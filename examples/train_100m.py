"""End-to-end training driver: a ~100M-parameter qwen3-family model with the
production trainer — instrumented profiling, checkpoint/restart, straggler
watchdog, LR schedule, phased synthetic corpus.

Default arguments are CPU-feasible (a few minutes); pass --steps 300
--seq-len 512 for the full run on a real machine.

    PYTHONPATH=src python examples/train_100m.py --steps 30
"""
import argparse
import dataclasses
import json
import os

from repro.configs import get_config
from repro.configs.base import ArchConfig, AttnConfig
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train import Trainer

# ~100M params: 12L, d=768, 12 heads, d_ff 2048, 32k vocab
CFG_100M = ArchConfig(
    name="qwen3-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    d_ff=2048,
    vocab_size=32768,
    attn=AttnConfig(n_heads=12, n_kv_heads=4, head_dim=64, qk_norm=True),
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts/ck_100m")
    ap.add_argument("--profile-out", default="artifacts/prof_100m")
    args = ap.parse_args()

    print(f"model: {CFG_100M.name}  params≈{CFG_100M.param_count()/1e6:.0f}M")
    tr = Trainer(CFG_100M, seq_len=args.seq_len, batch=args.batch,
                 opt=AdamWConfig(lr=3e-4),
                 lr_fn=linear_warmup_cosine(3e-4, args.steps // 10 + 1,
                                            args.steps),
                 microbatch=args.microbatch,
                 ckpt_dir=args.ckpt_dir, ckpt_every=10,
                 interval_steps=2.0)
    state = tr.run(args.steps, log_every=5)   # resumes automatically
    rep = tr.watchdog_report()
    print(json.dumps({
        "final_loss": tr.metrics_history[-1]["loss"],
        "first_loss": tr.metrics_history[0]["loss"],
        "mean_step_ms": 1e3 * sum(tr.step_times[1:]) / max(len(tr.step_times) - 1, 1),
        "stragglers": rep.slow_steps,
        "resume": "delete %s to restart from scratch" % args.ckpt_dir,
    }, indent=1))
    if tr.builder is not None:
        from repro.core import save_profile
        os.makedirs(args.profile_out, exist_ok=True)
        save_profile(args.profile_out, tr.profile())
        print("interval profile ->", args.profile_out)


if __name__ == "__main__":
    main()
