"""Batched serving with continuous batching + heterogeneous Nugget profiling.

Prefill and decode iterations emit different hook streams; the interval
profile mixes them — serving is the naturally phase-rich workload class.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import get_config, reduced
from repro.core import KMeansSelector
from repro.models.model_zoo import build_model
from repro.serve import ServeEngine, SyntheticRequests


def main():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, batch=4, max_seq=96, prefill_len=16,
                      interval_steps=3.0)
    gen = SyntheticRequests(cfg.vocab_size, prompt_len=12, mean_new=16,
                            seed=0)
    stats = eng.run(params, [gen.request(i) for i in range(12)])
    print("serving stats:",
          {k: round(v, 3) if isinstance(v, float) else v
           for k, v in stats.items()})

    profile = eng.profile()
    mix = {k: eng.kinds_log.count(k) for k in set(eng.kinds_log)}
    print(f"engine iterations by kind: {mix}")
    print(f"intervals: {profile.n_intervals} "
          f"(uow/step: prefill={profile.table.step_uow('prefill'):.0f}, "
          f"decode={profile.table.step_uow('decode'):.0f})")
    sel = KMeansSelector(seed=0).select(profile)
    print(f"k-means picked {len(sel.interval_ids)} representative intervals "
          f"with weights {[round(float(w), 2) for w in sel.weights]}")


if __name__ == "__main__":
    main()
