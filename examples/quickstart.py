"""Quickstart: the whole Nugget pipeline in ~60 lines (paper Fig. 1).

Train a small instrumented model, discover intervals, select representative
samples two ways, create nuggets, replay them natively, and compare the
predicted full-run time against the measured ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_config, reduced
from repro.core import (KMeansSelector, RandomSelector, ReplayEngine,
                        create_nuggets, measure_full_run, predict_total_time,
                        prediction_error)
from repro.train import Trainer

N_STEPS = 40


def main():
    cfg = reduced(get_config("olmoe-1b-7b"))      # 64->4 experts, tiny dims
    with tempfile.TemporaryDirectory() as ckdir:
        print(f"== training {cfg.name} (reduced) for {N_STEPS} steps, "
              "hooks ON")
        tr = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=ckdir, ckpt_every=10,
                     interval_steps=2.5)
        tr.run(N_STEPS)

        profile = tr.profile()
        print(f"== interval analysis: {profile.n_intervals} intervals, "
              f"{profile.total_uow:.0f} jaxpr-ops of work, "
              f"blocks={profile.table.names[:4]}...")

        runner = tr.make_runner()
        engine = ReplayEngine(runner, profile)
        actual = measure_full_run(runner, N_STEPS)

        for name, selector in (("random", RandomSelector(n_samples=8, seed=0)),
                               ("kmeans", KMeansSelector(seed=0))):
            sel = selector.select(profile)
            nuggets = create_nuggets(profile, sel, warmup_intervals=1,
                                     ckpt_every=10)
            results = engine.replay_all(nuggets)
            pred = predict_total_time(profile, results)
            err = prediction_error(pred, actual)
            print(f"== {name:7s}: {len(nuggets):2d} nuggets | "
                  f"predicted {pred:6.2f}s vs actual {actual:6.2f}s | "
                  f"error {err:+.1%}")


if __name__ == "__main__":
    main()
