"""The full research workflow the paper enables (§IV-B + §V-A), driven by
the artifact pipeline (``repro.pipeline``):

1. instrumented run -> interval profile (ProfileStage, cached),
2. two selection methodologies (Random / K-means+silhouette),
3. nugget creation with markers (MarkStage) + LOW-OVERHEAD marker search,
4. native validation on TWO platforms (f32 vs bf16 execution),
5. cross-platform consistency: speedup-prediction error + per-nugget
   variability — 'consistent error across platforms beats low error on one'.

Both selector runs share one artifact store, so the second run reuses the
cached profile and baselines and re-runs only select/mark/replay/validate.

    PYTHONPATH=src python examples/nugget_workflow.py
"""
import os
import tempfile

from repro.core import load_profile, plan_markers
from repro.pipeline import Pipeline, PipelineConfig

N_STEPS = 32


def run_method(store: str, selector: str, selector_args: dict):
    cfg = PipelineConfig(arch="olmoe-1b-7b", platforms=("f32", "bf16"),
                         selector=selector, selector_args=selector_args,
                         steps=N_STEPS, seq_len=32, batch=4,
                         interval_steps=2.5, seed=0)
    return Pipeline(cfg, store).run()


def main():
    store = os.environ.get("REPRO_STORE",
                           tempfile.mkdtemp(prefix="nugget-store-"))
    print(f"== artifact store: {store}")
    manifests = {}
    for mname, sargs in (("random", {"n_samples": 6, "seed": 0}),
                         ("kmeans", {"seed": 0})):
        manifests[mname] = run_method(store, mname, sargs)
        hits = manifests[mname]["cache_hits"]
        print(f"== {mname}: {hits} cache hits / "
              f"{manifests[mname]['cache_misses']} misses")

    # the profile is an inspectable artifact: load it back from the store
    prof_entry = next(s for s in manifests["random"]["stages"]
                      if s["kind"] == "profile")
    profile = load_profile(os.path.join(prof_entry["path"], "profile"))
    print(f"== {profile.n_intervals} intervals "
          f"(profile artifact {prof_entry['key'][:12]})")

    # marker study: true end marker vs low-overhead search
    plain = plan_markers(profile, 2, search_distance=0.0)
    cheap = plan_markers(profile, 2,
                         search_distance=0.4 * profile.step_uow)
    print(f"== markers for interval 2: end block "
          f"{profile.table.names[plain.end.block]} "
          f"(hook fraction {plain.hook_fraction:.3f}) vs low-overhead "
          f"{profile.table.names[cheap.end.block]} "
          f"(fraction {cheap.hook_fraction:.3f}, "
          f"precision loss {cheap.precision_loss_uow:.0f} uow)")

    for mname, manifest in manifests.items():
        m = manifest["metrics"]
        print(f"\n== {mname}: per-platform prediction error:",
              {p: f"{v['error']:+.1%}" for p, v in m["platforms"].items()})
        for e in m["speedup_errors"]:
            print(f"   speedup {e['pair']}: true {e['true_speedup']:.3f} "
                  f"pred {e['pred_speedup']:.3f} "
                  f"err {e['abs_speedup_error']:.1%}")
        rep = m["consistency"]
        print(f"   consistency: spread={rep['error_spread']:.3f} "
              f"=> {'TRUSTWORTHY' if rep['consistent'] else 'SUSPECT'}")
        worst = m["nugget_variability"][0]
        print(f"   most platform-sensitive nugget: id {worst['nugget_id']} "
              f"(rel-cost spread {worst['rel_cost_spread']:.3f})")


if __name__ == "__main__":
    main()
