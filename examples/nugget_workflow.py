"""The full research workflow the paper enables (§IV-B + §V-A):

1. instrumented run -> interval profile (hooks, near-native speed),
2. two selection methodologies (Random / K-means+silhouette),
3. nugget creation with markers, warmup, LOW-OVERHEAD marker search,
4. native validation on TWO platforms (f32 vs bf16 execution),
5. cross-platform consistency: speedup-prediction error + per-nugget
   variability — 'consistent error across platforms beats low error on one'.

    PYTHONPATH=src python examples/nugget_workflow.py
"""
import dataclasses

from repro.configs import get_config, reduced
from repro.core import (KMeansSelector, PlatformResult, RandomSelector,
                        ReplayEngine, consistency_report, create_nuggets,
                        measure_full_run, nugget_variability, plan_markers,
                        predict_total_time, speedup_error_matrix)
from repro.train import Trainer

N_STEPS = 32


def main():
    base = reduced(get_config("olmoe-1b-7b"))
    platforms = {
        "f32": dataclasses.replace(base, compute_dtype="float32"),
        "bf16": dataclasses.replace(base, compute_dtype="bfloat16"),
    }
    trainers = {}
    for name, cfg in platforms.items():
        print(f"== profiling run on platform {name}")
        tr = Trainer(cfg, seq_len=32, batch=4, interval_steps=2.5, seed=0,
                     donate=False)
        tr.run(N_STEPS)
        trainers[name] = tr
    profile = trainers["f32"].profile()
    print(f"== {profile.n_intervals} intervals")

    # marker study: true end marker vs low-overhead search
    plain = plan_markers(profile, 2, search_distance=0.0)
    cheap = plan_markers(profile, 2,
                         search_distance=0.4 * profile.step_uow)
    print(f"== markers for interval 2: end block "
          f"{profile.table.names[plain.end.block]} "
          f"(hook fraction {plain.hook_fraction:.3f}) vs low-overhead "
          f"{profile.table.names[cheap.end.block]} "
          f"(fraction {cheap.hook_fraction:.3f}, "
          f"precision loss {cheap.precision_loss_uow:.0f} uow)")

    for mname, selector in (("random", RandomSelector(n_samples=6, seed=0)),
                            ("kmeans", KMeansSelector(seed=0))):
        sel = selector.select(profile)
        nuggets = create_nuggets(profile, sel, warmup_intervals=1)
        plats, results_by = [], {}
        for pname, tr in trainers.items():
            runner = tr.make_runner()
            eng = ReplayEngine(runner, profile)
            res = eng.replay_all(nuggets)
            results_by[pname] = res
            plats.append(PlatformResult(
                pname, predict_total_time(profile, res),
                measure_full_run(runner, N_STEPS)))
        print(f"\n== {mname}: per-platform prediction error:",
              {p.platform: f"{p.error:+.1%}" for p in plats})
        for e in speedup_error_matrix(plats):
            print(f"   speedup {e['pair']}: true {e['true_speedup']:.3f} "
                  f"pred {e['pred_speedup']:.3f} "
                  f"err {e['abs_speedup_error']:.1%}")
        rep = consistency_report(plats)
        print(f"   consistency: spread={rep['error_spread']:.3f} "
              f"=> {'TRUSTWORTHY' if rep['consistent'] else 'SUSPECT'}")
        worst = nugget_variability(results_by)[0]
        print(f"   most platform-sensitive nugget: id {worst['nugget_id']} "
              f"(rel-cost spread {worst['rel_cost_spread']:.3f})")


if __name__ == "__main__":
    main()
