"""Paper §V-B analogue: use nugget-sized programs as organic microbenchmarks
to localize where the backend diverges from the portable-IR view.

We compare the jaxpr (portable IR) op histogram of a step against the
compiled HLO op histogram and print the biggest "microcoding" deltas — the
workflow that found gem5's paired-memory-op bug, retargeted at XLA fusion.

    PYTHONPATH=src python examples/model_accuracy.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.bench_model_accuracy import jaxpr_histogram
from repro.configs import get_config, reduced
from repro.core.hlo_analysis import histogram_delta, op_histogram
from repro.models.model_zoo import build_model


def main():
    for arch in ("qwen3-1.7b", "mamba2-780m", "olmoe-1b-7b"):
        cfg = reduced(get_config(arch))
        m = build_model(cfg)
        params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        toks = jax.ShapeDtypeStruct((2, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}

        def fn(p, b):
            return m.loss(p, b)[0]

        jh = jaxpr_histogram(jax.make_jaxpr(fn)(params, batch))
        hh = op_histogram(jax.jit(fn).lower(params, batch).compile().as_text())
        print(f"\n== {arch}: portable-IR ops {sum(jh.values()):.0f} vs "
              f"compiled ops {sum(hh.values())} "
              f"(fusion ratio {sum(jh.values()) / sum(hh.values()):.2f}x)")
        print("   top microcoding deltas (op, IR count, HLO count):")
        for op, a, b in histogram_delta({k: int(v) for k, v in jh.items()},
                                        hh)[:6]:
            print(f"     {op:24s} {a:6d} {b:6d}")


if __name__ == "__main__":
    main()
