"""Atomic, async, keep-N checkpointing with manifest + checksums.

Layout::

    <dir>/step_00000123/
        arrays_p0.npz      # flattened keypath -> array (per process)
        manifest.json      # step, keys, checksums, writer metadata
    <dir>/LATEST           # name of last committed checkpoint (atomic rename)

Commit protocol (crash-safe): write into ``.tmp-step_X``, fsync files, rename
dir, then rewrite LATEST via tmp+rename.  A partially-written checkpoint can
never be observed as committed — the restart path always reads LATEST.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _checksum(arrays: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:1 << 20])
    return h.hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, *, keep_n: int = 3,
                 process_index: int = 0, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.pidx = process_index
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             *, blocking: bool = False) -> None:
        # snapshot to host memory NOW (donated/updated buffers must not race)
        arrays = _flatten(tree)
        if self._pool is None or blocking:
            self._write(step, arrays, extra or {})
            return
        self.wait()                       # only one in-flight save
        self._pending = self._pool.submit(self._write, step, arrays,
                                          extra or {})

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, arrays: Dict[str, np.ndarray],
               extra: Dict) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp-{name}-{self.pidx}")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        npz_path = os.path.join(tmp, f"arrays_p{self.pidx}.npz")
        np.savez(npz_path, **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "checksum": _checksum(arrays),
            "time": time.time(),
            "process": self.pidx,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._commit_latest(name)
            self._gc()

    def _commit_latest(self, name: str) -> None:
        tmp = os.path.join(self.dir, ".LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_"):
                try:
                    out.append(int(n[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        full = os.path.join(self.dir, name)
        if not os.path.exists(os.path.join(full, "manifest.json")):
            return None
        return int(name[5:])

    def restore(self, template: Any, step: Optional[int] = None,
                *, shardings: Any = None, verify: bool = True
                ) -> Tuple[Any, Dict]:
        """Restore into ``template``'s structure; optionally device_put with
        ``shardings`` (elastic restore onto a different mesh)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        name = f"step_{step:08d}"
        full = os.path.join(self.dir, name)
        with open(os.path.join(full, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(full, f"arrays_p{self.pidx}.npz"))
        arrays = {k: z[k] for k in z.files}
        if verify and _checksum(arrays) != manifest["checksum"]:
            raise IOError(f"checksum mismatch restoring {full}")
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_flat = (jax.tree.leaves(shardings,
                                      is_leaf=lambda x: hasattr(x, "spec"))
                      if shardings is not None else None)
        for i, (path, leaf) in enumerate(flat_t):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = arrays[key]
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr.astype(leaf.dtype)
                                             if hasattr(leaf, "dtype") else arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        return tree, manifest.get("extra", {})
