"""Pallas TPU Mamba2/SSD intra-chunk kernel.

The SSD layer splits into (a) an O(q^2) *intra-chunk* part (attention-like
masked-decay matmuls — the MXU hot spot) and (b) an O(nchunk) sequential
state recurrence.  The kernel computes, per (batch, head, chunk):

    y_intra = (L ∘ (C B^T)) Xdt          [q, hp]
    s_chunk = B^T (decay_out ∘ Xdt)      [hp, N] contribution to the state
    decay   = exp(cum[-1])               total chunk decay

The cheap inter-chunk recurrence + C·h_in inter term run as a lax.scan in
``ops.ssd`` — this mirrors how the CUDA SSD kernel is adapted to the TPU's
(MXU + sequential-grid) execution model (DESIGN.md §2 hardware adaptation).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                                   # pragma: no cover
    pltpu = None
    _VMEM = None


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, s_ref, dec_ref, *, q: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [q, hp]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [q]
    A = a_ref[0]                                       # scalar (<0)
    B = b_ref[0, 0].astype(jnp.float32)                # [q, N]
    C = c_ref[0, 0].astype(jnp.float32)                # [q, N]

    la = dt * A                                        # log decay per step
    cum = jnp.cumsum(la)                               # [q]
    xdt = x * dt[:, None]

    rel = cum[:, None] - cum[None, :]                  # [q, q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    Lk = jnp.exp(jnp.where(tri, rel, -jnp.inf))
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [q,q]
    y_ref[0, :, 0, :] = (jax.lax.dot_general(
        Lk * cb, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(y_ref.dtype)

    decay_out = jnp.exp(cum[-1] - cum)                 # [q]
    s_chunk = jax.lax.dot_general(
        xdt * decay_out[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # [hp, N]
    s_ref[0, 0, 0] = s_chunk.astype(s_ref.dtype)
    dec_ref[0, 0, 0] = jnp.exp(cum[-1])


def ssd_intra(xh: jax.Array, dt: jax.Array, A: jax.Array, Bp: jax.Array,
              Cp: jax.Array, chunk: int, *, interpret: bool = True):
    """xh: [B,S,nh,hp]; dt: [B,S,nh] f32; A: [nh]; Bp/Cp: [B,S,N].
    Returns (y_intra [B,S,nh,hp] f32, s_chunk [B,nc,nh,hp,N] f32,
    decay [B,nc,nh] f32, cum [B,nc,q,nh])."""
    b, s, nh, hp = xh.shape
    n = Bp.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0)))
    s_pad = nc * q
    Bq = Bp.reshape(b, nc, q, n)
    Cq = Cp.reshape(b, nc, q, n)

    kernel = functools.partial(_ssd_kernel, q=q)
    y, s_chunk, dec = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, hp), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, q, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, cc: (bb, cc, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, cc: (bb, cc, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, hp), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, 1, 1, hp, n), lambda bb, hh, cc: (bb, cc, hh, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, cc: (bb, cc, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s_pad, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh, hp, n), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dt, A, Bq, Cq)
    # cum is recomputed cheaply outside for the inter-chunk term
    la = (dt * A[None, None, :]).reshape(b, nc, q, nh)
    cum = jnp.cumsum(la, axis=2)
    return y, s_chunk, dec, cum
