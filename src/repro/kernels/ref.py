"""Pure-jnp oracles for every kernel (the allclose targets for the
shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, group: int, causal: bool = True,
                        window=None, cap: float = 0.0) -> jax.Array:
    """q: [B,S,H,hd]; k/v: [B,Sk,KV,hd]."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(hd)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    d = qp - kp
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= d >= 0
    w = -1 if window is None else int(window)
    if w >= 0:
        ok &= d < w
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, lengths, *, group: int,
                     window=None, cap: float = 0.0) -> jax.Array:
    """q: [B,1,H,hd]; caches [B,S,KV,hd]; lengths [B]."""
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    kr = jnp.repeat(k_cache, group, axis=2)
    vr = jnp.repeat(v_cache, group, axis=2)
    sc = jnp.einsum("bohd,bkhd->bhk", q.astype(jnp.float32),
                    kr.astype(jnp.float32)) / math.sqrt(hd)
    if cap > 0:
        sc = cap * jnp.tanh(sc / cap)
    cur = (lengths - 1)[:, None]
    kp = jnp.arange(s)[None, :]
    d = cur - kp
    ok = d >= 0
    w = -1 if window is None else int(window)
    if w >= 0:
        ok &= d < w
    sc = jnp.where(ok[:, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


def ssd_ref(xh, dt, A, Bp, Cp):
    """Sequential SSD recurrence oracle.
    xh: [B,S,nh,hp]; dt: [B,S,nh]; A: [nh]; Bp/Cp: [B,S,N].
    Returns (y [B,S,nh,hp] f32, h_final [B,nh,hp,N] f32)."""
    b, s, nh, hp = xh.shape
    n = Bp.shape[-1]

    def step(h, xs):
        xt, dtt, bt, ct = xs
        a = jnp.exp(dtt * A[None])
        dx = xt * dtt[..., None]
        h = a[..., None, None] * h + jnp.einsum("bhp,bn->bhpn", dx, bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32)
    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bp.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cp.astype(jnp.float32), 1, 0))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_fin
