"""Pallas TPU flash attention (GQA, causal, sliding-window, soft-cap).

Grid (B, H, nQ, nK); the kv dimension is innermost ("arbitrary") so the
online-softmax state (m, l, acc) lives in VMEM scratch across kv blocks.
GQA is expressed in the BlockSpec index maps (q head h reads kv head h//g) —
no materialized KV repetition.  Block shapes default to (128, 128): MXU-
aligned tiles; VMEM working set per step =
bq*hd + bk*hd (q,k,v tiles) + bq*(hd+2) f32 scratch ≈ 0.2 MB at hd=128.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                                   # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(win_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bq: int, bk: int, n_kv: int,
                  kv_len: int, causal: bool, cap: float, scale: float):
    i_q = pl.program_id(2)
    i_kv = pl.program_id(3)

    @pl.when(i_kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)

    q_pos = i_q * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = i_kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d = q_pos - k_pos
    ok = k_pos < kv_len                  # mask padded keys
    if causal:
        ok &= d >= 0
    win = win_ref[0]
    ok &= (win < 0) | (d < win)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                               # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i_kv == n_kv - 1)
    def _write():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    group: int, causal: bool = True,
                    window: Optional[jax.Array] = None,
                    cap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B,S,H,hd]; k/v: [B,S,KV,hd] with H = KV*group.  Positions are
    arange (rope applied by the caller)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    assert h == kv * group
    bq = min(bq, s)
    bk = min(bk, s)
    n_q = -(-s // bq)
    n_k = -(-s // bk)
    pad_q = n_q * bq - s
    pad_k = n_k * bk - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    win = jnp.asarray([-1 if window is None else window], jnp.int32) \
        if not isinstance(window, jax.Array) else window.reshape(1)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_k, kv_len=s, causal=causal,
        cap=cap, scale=1.0 / math.sqrt(hd))
    grid = (b, h, n_q, n_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, iq, ik: (0,)),
            pl.BlockSpec((1, bq, 1, hd), lambda bb, hh, iq, ik: (bb, iq, hh, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, hh, iq, ik: (bb, ik, hh // group, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, hh, iq, ik: (bb, ik, hh // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bb, hh, iq, ik: (bb, iq, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_q * bq, h, hd), q.dtype),
        scratch_shapes=([_VMEM((bq, 1), jnp.float32),
                         _VMEM((bq, 1), jnp.float32),
                         _VMEM((bq, hd), jnp.float32)] if _VMEM else []),
        interpret=interpret,
    )(win, q, k, v)
    return out[:, :s]
