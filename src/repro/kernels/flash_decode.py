"""Pallas TPU flash-decode: single-token attention over a long KV cache,
partitioned over kv blocks with online-softmax (LSE) combination — the
kernel twin of the seq-sharded decode softmax the SPMD partitioner builds
for ``long_500k`` (DESIGN.md).

Grid (B, H, nK), kv innermost; per-row cache lengths come in as a [B] array
read per block; scratch carries (m, l, acc) per (b, h).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                                   # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _decode_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bk: int, n_kv: int,
                   cap: float, scale: float):
    i_kv = pl.program_id(2)

    @pl.when(i_kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0, :].astype(jnp.float32)          # [hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (k @ q) * scale                                # [bk]
    if cap > 0:
        s = cap * jnp.tanh(s / cap)

    cur = len_ref[0] - 1                               # query position
    k_pos = i_kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    d = cur - k_pos
    win = win_ref[0]
    ok = (d >= 0) & ((win < 0) | (d < win))
    s = jnp.where(ok, s, NEG_INF)
    s = s[None, :]                                     # [1, bk]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(i_kv == n_kv - 1)
    def _write():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, 0, :] = (acc_scr[...] / l)[0].astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, group: int,
                 window: Optional[jax.Array] = None, cap: float = 0.0,
                 bk: int = 256, interpret: bool = True) -> jax.Array:
    """q: [B,1,H,hd]; caches: [B,S,KV,hd]; lengths: [B] (valid entries incl.
    the current token)."""
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    bk = min(bk, s)
    n_k = -(-s // bk)
    pad_k = n_k * bk - s
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    win = jnp.asarray([-1 if window is None else window], jnp.int32) \
        if not isinstance(window, jax.Array) else window.reshape(1)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, bk=bk, n_kv=n_k, cap=cap,
                               scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, ik: (bb,)),
            pl.BlockSpec((1,), lambda bb, hh, ik: (0,)),
            pl.BlockSpec((1, 1, 1, hd), lambda bb, hh, ik: (bb, 0, hh, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, hh, ik: (bb, ik, hh // group, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, hh, ik: (bb, ik, hh // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda bb, hh, ik: (bb, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, h, hd), q.dtype),
        scratch_shapes=([_VMEM((1, 1), jnp.float32),
                         _VMEM((1, 1), jnp.float32),
                         _VMEM((1, hd), jnp.float32)] if _VMEM else []),
        interpret=interpret,
    )(lengths, win, q, k_cache, v_cache)
    return out
