"""jit'd wrappers assembling full operations from the Pallas kernels.

``ssd`` composes the intra-chunk kernel with the cheap inter-chunk
recurrence (lax.scan) and the C·h_in inter-chunk output term.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.ssd import ssd_intra


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, group: int,
                    causal: bool = True, window=None, cap: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Model-facing signature (positions are arange; rope pre-applied)."""
    return _flash(q, k, v, group=group, causal=causal, window=window,
                  cap=cap, bq=bq, bk=bk, interpret=interpret)


def flash_decode(q, k_cache, v_cache, lengths, *, group: int, window=None,
                 cap: float = 0.0, bk: int = 256,
                 interpret: bool = True) -> jax.Array:
    return _flash_decode(q, k_cache, v_cache, lengths, group=group,
                         window=window, cap=cap, bk=bk, interpret=interpret)


def ssd(xh, dt, A, Bp, Cp, *, chunk: int = 256,
        interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full SSD layer: Pallas intra-chunk + lax.scan inter-chunk.
    Returns (y [B,S,nh,hp] f32, h_final [B,nh,hp,N] f32)."""
    b, s, nh, hp = xh.shape
    n = Bp.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    y_intra, s_chunk, dec, cum = ssd_intra(xh, dt, A, Bp, Cp, chunk,
                                           interpret=interpret)
    pad = nc * q - s
    Cq = (jnp.pad(Cp, ((0, 0), (0, pad), (0, 0))) if pad else Cp) \
        .astype(jnp.float32).reshape(b, nc, q, n)

    def chunk_step(h, xs):
        s_c, dec_c, c_c, cum_c = xs
        # inter-chunk output: C_t · h_in * exp(cum_t)
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", c_c, h, jnp.exp(cum_c))
        h = dec_c[:, :, None, None] * h + s_c
        return h, y_inter

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32)
    xs = (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(dec, 1, 0),
          jnp.moveaxis(Cq, 1, 0), jnp.moveaxis(cum, 1, 0))
    h_fin, y_inter = jax.lax.scan(chunk_step, h0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1).reshape(b, nc * q, nh, hp)[:, :s]
    return y_intra[:, :s] + y_inter, h_fin
