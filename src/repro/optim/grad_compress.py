"""int8 error-feedback gradient compression for the DP all-reduce
(distributed-optimization trick; optional trainer mode).

Each leaf is quantized to int8 with a per-leaf scale before the cross-replica
sum; the quantization residual is carried in an error-feedback buffer so the
bias vanishes over steps (EF-SGD).  Implemented in a shard_map over the data
axis so the collective really moves int8 (XLA would otherwise all-reduce
f32); wire format is 4x smaller.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, ef: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (int8 payload, scale, new error-feedback)."""
    target = g.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    deq = dequantize(q, scale)
    return q, scale, target - deq


def compressed_psum(grads, ef, axis_name: str):
    """Inside shard_map: quantize+EF, int8 psum, dequantize with summed
    scales.  Scales are psum-averaged (each shard dequantizes its own scale
    before summing would need 2 passes; we sum q*scale via scale-normalized
    trick: send q and scale separately, psum(q * 1) with per-shard scale
    applied after a scale all-gather is equivalent to psum of deq when using
    a shared max-scale).  We use the shared-max-scale variant: one extra
    scalar psum (max) fixes every shard to the same scale, so
    psum(int8) * scale == sum of dequantized grads exactly.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(target))
        gmax = jax.lax.pmax(local_max, axis_name)
        scale = jnp.maximum(gmax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_e = target - q.astype(jnp.float32) * scale
        # int8 payload summed in int32 (wire: int8; accum: widened)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * scale) / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out, new_ef = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = one(g, e)
        out.append(o)
        new_ef.append(ne)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_ef)


def compression_ratio(grads) -> float:
    fp_bytes = sum(g.size * 4 for g in jax.tree.leaves(grads))
    q_bytes = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return fp_bytes / q_bytes
