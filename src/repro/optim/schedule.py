"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def linear_warmup_cosine(lr: float, warmup: int, total: int,
                         final_frac: float = 0.1) -> Callable:
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(s < warmup, warm, lr * cos)
    return f


def constant(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)
