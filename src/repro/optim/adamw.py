"""AdamW with bf16 params + f32 master copy & moments (production layout:
master/m/v are FSDP×TP sharded exactly like the params, so per-chip optimizer
memory is params_bytes*12/n_chips).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_master: bool = True      # keep f32 master when params are bf16


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any                  # f32 copy (or None-like empty dict)


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.use_master else jax.tree.map(lambda p: jnp.zeros((0,)), params))
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                    master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig,
                 lr: jax.Array) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads32, gnorm = clip_by_global_norm(grads32, cfg.grad_clip)
    else:
        gnorm = global_norm(grads32)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads32)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads32)

    def upd(p_master, m, v):
        mh = m / b1c
        vh = v / b2c
        return p_master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                + cfg.weight_decay * p_master)

    if cfg.use_master:
        master = jax.tree.map(upd, state.master, mu, nu)
        new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                                  master, params)
    else:
        master = state.master
        new_params = jax.tree.map(
            lambda p, m, v: upd(p.astype(jnp.float32), m, v).astype(p.dtype),
            params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu, master), metrics


def opt_state_axes(param_axes, cfg: AdamWConfig):
    """Logical axes for the optimizer state (mirrors param sharding)."""
    empty = jax.tree.map(lambda a: a if cfg.use_master else (None,), param_axes)
    return OptState((), param_axes, param_axes, empty)
