from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, OptState, adamw_update, clip_by_global_norm, global_norm,
    init_opt_state, opt_state_axes,
)
from repro.optim.schedule import constant, linear_warmup_cosine  # noqa: F401
from repro.optim.grad_compress import (  # noqa: F401
    compressed_psum, compression_ratio, init_error_feedback, quantize_int8,
    dequantize,
)
