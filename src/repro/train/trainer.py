"""Instrumented trainer: the paper's "interval analysis executable" is this
loop with profiling on (DESIGN.md §3).  Features:

- WorkMeter hooks inside the jit'd step + host-side IntervalBuilder
  (per-step dynamic signature entries from the loss aux),
- microbatch gradient accumulation, donated buffers,
- atomic async checkpointing + exact resume (stateless data cursor),
- step watchdog: straggler detection/logging (slow-step quarantine list),
- replay support: ``make_runner()`` exposes the run as a StepRunner so
  ReplayEngine can validate nuggets on this platform.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.blocks_lm import build_block_table
from repro.core.intervals import IntervalBuilder, Profile
from repro.core.meter import materialize_dyn, read_meter
from repro.core.registry import BlockTable
from repro.core.replay import SimpleRunner
from repro.models.model_zoo import Model, build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import constant
from repro.train.state import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class WatchdogReport:
    slow_steps: List[int]
    step_times: List[float]

    def straggler_fraction(self) -> float:
        return len(self.slow_steps) / max(len(self.step_times), 1)


class Trainer:
    def __init__(self, cfg: ArchConfig, *, shape: Optional[ShapeConfig] = None,
                 seq_len: int = 128, batch: int = 4,
                 opt: Optional[AdamWConfig] = None,
                 lr_fn: Optional[Callable] = None,
                 data=None, seed: int = 0,
                 instrument: bool = True,
                 interval_steps: float = 2.0,
                 microbatch: int = 1,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 keep_n: int = 3,
                 straggler_factor: float = 3.0,
                 donate: bool = True,
                 defer_analysis: bool = True,
                 history_cap: int = 1024):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.shape = shape or ShapeConfig("adhoc_train", "train", seq_len, batch)
        self.opt_cfg = opt or AdamWConfig()
        self.lr_fn = lr_fn or constant(self.opt_cfg.lr)
        self.seed = seed
        self.instrument = instrument
        self.microbatch = microbatch
        self.straggler_factor = straggler_factor

        if data is None:
            from repro.data.synthetic import SyntheticCorpus
            data = SyntheticCorpus(
                cfg.vocab_size, self.shape.seq_len, self.shape.global_batch,
                seed=seed,
                n_frames=cfg.n_frames if cfg.family == "encdec" else 0,
                d_model=cfg.d_model, n_patches=cfg.n_patches)
        self.data = data

        self.table: Optional[BlockTable] = (
            build_block_table(self.model, self.shape) if instrument else None)
        self.interval_uow = (interval_steps * self.table.step_uow()
                             if self.table else 0.0)
        # defer_analysis=True (the default) only logs steps during training
        # (near-zero host-side cost per step) and batch-analyzes at
        # profile() through the vectorized path; False = legacy per-step
        # replay inside the training loop
        self.builder = (IntervalBuilder(self.table, self.interval_uow,
                                        defer=defer_analysis)
                        if self.table else None)

        step_fn = make_train_step(self.model, self.opt_cfg, self.lr_fn,
                                  table=self.table, microbatch=microbatch,
                                  instrument=instrument)
        self._step_fn = (jax.jit(step_fn, donate_argnums=(0,)) if donate
                         else jax.jit(step_fn))
        self._uninstrumented = jax.jit(
            make_train_step(self.model, self.opt_cfg, self.lr_fn,
                            table=None, microbatch=microbatch,
                            instrument=False),
            donate_argnums=(0,))

        self.ckpt = (Checkpointer(ckpt_dir, keep_n=keep_n)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.step_times: List[float] = []
        self.slow_steps: List[int] = []
        # bounded recent-step window (long runs used to grow without limit);
        # full-run aggregates live in the repro.obs MetricsRegistry
        self.metrics_history: Deque[Dict[str, float]] = \
            deque(maxlen=max(history_cap, 1))
        self._tokens_per_step = self.shape.tokens
        # batched end-of-run readback of the device meter (one device sync
        # per run, not per interval); see read_meters in core/meter.py
        self.meter_reading: Optional[Dict[str, np.ndarray]] = None

    # ------------------------------------------------------------------
    def init_state(self) -> TrainState:
        return init_train_state(self.model, jax.random.PRNGKey(self.seed),
                                self.opt_cfg, self.table)

    def _device_batch(self, step: int) -> Dict[str, jax.Array]:
        b = self.data.batch_at(step)
        return {k: jnp.asarray(v) for k, v in b.items() if k != "domains"}

    def run(self, n_steps: int, *, state: Optional[TrainState] = None,
            resume: bool = True, log_every: int = 0) -> TrainState:
        if state is None:
            state = self.init_state()
            if resume and self.ckpt is not None:
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, extra = self.ckpt.restore(state)
                    log.info("resumed from step %s", latest)
        start = int(state.step)
        with obs.span("train.run", start=start, steps=n_steps):
            for s in range(start, n_steps):
                batch = self._device_batch(s)
                t0 = time.perf_counter()
                state, metrics, aux = self._step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._post_step(s, dt, metrics, aux)
                if (self.ckpt is not None and self.ckpt_every
                        and (s + 1) % self.ckpt_every == 0):
                    self.ckpt.save(s + 1, state)
                if log_every and (s + 1) % log_every == 0:
                    log.info("step %d loss %.4f (%.0f ms)", s + 1,
                             float(metrics["loss"]), dt * 1e3)
            if self.ckpt is not None:
                self.ckpt.wait()
            self._drain_device(state)
        return state

    def _drain_device(self, state: TrainState) -> None:
        """End-of-run device drain: one batched meter readback plus one
        chunked fetch of any device-resident dynamic step-log entries —
        the hot loop itself never blocks on a device->host transfer."""
        if state.meter is not None:
            self.meter_reading = read_meter(state.meter)
        if self.builder is not None:
            materialize_dyn(self.builder.step_log)

    def _post_step(self, step: int, dt: float, metrics, aux) -> None:
        self.step_times.append(dt)
        med = float(np.median(self.step_times[-50:]))
        if len(self.step_times) > 5 and dt > self.straggler_factor * med:
            self.slow_steps.append(step)
            obs.metrics().count("train.stragglers")
            log.warning("straggler: step %d took %.0f ms (median %.0f ms)",
                        step, dt * 1e3, med * 1e3)
        row = {k: float(v) for k, v in metrics.items()}
        self.metrics_history.append(row)
        m = obs.metrics()
        m.count("train.steps")
        m.observe("train.step_s", dt)
        m.record("train.loss", row.get("loss", 0.0))
        m.record("train.tokens_per_s", self._tokens_per_step / max(dt, 1e-9))
        if self.builder is not None:
            dyn = {}
            deferred = self.builder.deferred
            for k in ("expert_tokens", "dropped_tokens"):
                if k in aux:
                    # deferred builders log the device array as-is — no
                    # per-step host sync; _drain_device fetches them in
                    # chunked batches after the run (materialize_dyn)
                    dyn[k] = aux[k] if deferred else np.asarray(aux[k])
            self.builder.add_step(dyn or None)

    # ------------------------------------------------------------------
    def profile(self, *, max_workers: Optional[int] = None,
                chunk_steps: Optional[int] = None) -> Profile:
        """Finalize the profile.  ``max_workers > 1`` shards the deferred
        step stream into chunks analyzed on a thread pool and merged in
        stream order — bit-for-bit identical to the serial finalize."""
        assert self.builder is not None, "instrumentation disabled"
        materialize_dyn(self.builder.step_log)
        with obs.span("train.profile_finalize",
                      workers=int(max_workers or 0)):
            if max_workers is not None and max_workers > 1:
                return self.builder.finalize_parallel(
                    chunk_steps=chunk_steps, max_workers=max_workers)
            return self.builder.finalize()

    def watchdog_report(self) -> WatchdogReport:
        return WatchdogReport(self.slow_steps, self.step_times)

    # ------------------------------------------------------------------
    def make_runner(self, *, instrument: bool = False) -> SimpleRunner:
        """StepRunner for ReplayEngine: reset() re-inits (or restores) at a
        step; run_step() executes one deterministic step (stateless data)."""
        step_fn = self._step_fn if instrument else self._uninstrumented

        def reset(step: int) -> TrainState:
            state = self.init_state()
            if step > 0 and self.ckpt is not None:
                steps = [s for s in self.ckpt.all_steps() if s <= step]
                if steps:
                    state, _ = self.ckpt.restore(state, steps[-1])
            return state

        def run(state: TrainState, step: int) -> TrainState:
            # fast-forward gap (checkpoint granularity) executes real steps
            batch = self._device_batch(step)
            state, _, _ = step_fn(state, batch)
            return state

        def sync(state: TrainState) -> None:
            jax.block_until_ready(state.params)

        return SimpleRunner(reset, run, sync)
