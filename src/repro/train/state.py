"""Train state + step construction (pure functions; the Trainer wires I/O)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, dtype_of
from repro.core.meter import init_meter, tick_step
from repro.core.registry import BlockTable
from repro.models.model_zoo import Model
from repro.optim.adamw import (AdamWConfig, OptState, adamw_update,
                               init_opt_state)


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: OptState
    rng: jax.Array
    meter: Optional[Dict[str, jax.Array]]


def init_train_state(model: Model, key: jax.Array, opt_cfg: AdamWConfig,
                     table: Optional[BlockTable] = None) -> TrainState:
    params = model.init(key)
    opt = init_opt_state(params, opt_cfg)
    meter = init_meter(table) if table is not None else None
    state = TrainState(jnp.zeros((), jnp.int32), params, opt,
                       jax.random.fold_in(key, 1), meter)
    # JAX caches equal constants: distinct zero leaves can alias the same
    # buffer, which breaks donate_argnums ("donate the same buffer twice").
    # Copy each leaf so every leaf owns its buffer.
    return jax.tree.map(lambda x: x.copy() if hasattr(x, "copy") else x, state)


def make_train_step(model: Model, opt_cfg: AdamWConfig, lr_fn: Callable,
                    *, table: Optional[BlockTable] = None,
                    microbatch: int = 1,
                    instrument: bool = True) -> Callable:
    """Build the jit-able train step: (state, batch) -> (state, metrics, aux).

    ``microbatch`` > 1 splits the global batch into that many accumulation
    slices (lax.scan, f32 accumulators) — the activation-memory lever for the
    123B-arch cells.  When ``instrument`` and a BlockTable is given the
    WorkMeter hook (paper §III-C1) runs inside the step.
    """
    def loss_fn(params, batch, rng):
        return model.loss(params, batch, rng=rng)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        rng = jax.random.fold_in(state.rng, state.step)
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mslice):
                gacc, lacc, aux_acc = carry
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mslice, rng)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatch,
                    gacc, g)
                aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
                return (gacc, lacc + l / microbatch, aux_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            m0 = jax.tree.map(lambda x: x[0], mb)
            aux0 = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                jax.eval_shape(lambda: loss_fn(state.params, m0, rng)[1]))
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32), aux0), mb)
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch, rng)

        lr = lr_fn(state.step)
        new_params, new_opt, om = adamw_update(state.params, grads,
                                               state.opt, opt_cfg, lr)
        meter = state.meter
        if instrument and table is not None and meter is not None:
            meter = tick_step(meter, table, aux)
        metrics = {"loss": loss, **om}
        new_state = TrainState(state.step + 1, new_params, new_opt,
                               state.rng, meter)
        return new_state, metrics, aux

    return train_step
