from repro.train.state import TrainState, init_train_state, make_train_step  # noqa: F401
from repro.train.trainer import Trainer, WatchdogReport  # noqa: F401
