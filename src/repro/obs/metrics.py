"""MetricsRegistry: counters, gauges and histograms for the lifecycle.

The registry is the always-on half of ``repro.obs`` (spans can be switched
off; metric updates are cheap enough to leave on everywhere): cache
hits/misses and put-bytes from the ``ArtifactStore``, intervals/s from the
batch analyzer, per-step loss/wall-time/tokens-per-s from ``Trainer`` and
``ServeEngine``, unit-of-work totals from ``WorkMeter`` readbacks.

Three instrument kinds, all thread-safe under one registry lock:

- ``Counter``  — monotone float/int total (``inc``),
- ``Gauge``    — last-write-wins value (``set``),
- ``Histogram``— count/sum/min/max plus a bounded reservoir of recent
  observations for percentile estimates (``observe``).

``snapshot()`` returns a plain-JSON dict (embedded into the pipeline run
manifest); ``report()`` renders a human table for ``--report`` CLIs.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from typing import Any, Deque, Dict, List, Optional


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self) -> Dict[str, Any]:
        v = self.value
        return {"type": "counter", "value": int(v) if v == int(v) else v}


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Streaming count/sum/min/max + a bounded ring of recent observations
    (``window``) from which quantiles are estimated.  The ring bounds
    memory for arbitrarily long runs — the full-run aggregates stay exact,
    quantiles reflect the recent window."""

    __slots__ = ("name", "count", "sum", "min", "max", "_recent")

    def __init__(self, name: str, window: int = 512):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._recent: Deque[float] = deque(maxlen=window)

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._recent.append(v)

    def quantile(self, q: float) -> float:
        if not self._recent:
            return 0.0
        vals = sorted(self._recent)
        i = min(len(vals) - 1, max(0, int(q * (len(vals) - 1) + 0.5)))
        return vals[i]

    def snapshot(self) -> Dict[str, Any]:
        if not self.count:
            return {"type": "histogram", "count": 0}
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "mean": self.sum / self.count, "min": self.min,
                "max": self.max, "p50": self.quantile(0.5),
                "p95": self.quantile(0.95)}


class MetricsRegistry:
    """Named instruments behind one lock.  Accessors are
    get-or-create, so call sites never pre-register; the convenience
    mutators (``count``/``record``/``observe``) are single calls usable
    from hot loops."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    # -- instruments ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 512) -> Histogram:
        return self._get(name, Histogram, window=window)

    # -- one-call mutators ----------------------------------------------
    def count(self, name: str, amount: float = 1.0) -> None:
        self.counter(name).inc(amount)

    def record(self, name: str, value: float) -> None:
        self.gauge(name).set(value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    # -- export ---------------------------------------------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def value(self, name: str) -> Optional[float]:
        """Counter/gauge value (None if absent; histograms use snapshot)."""
        with self._lock:
            m = self._metrics.get(name)
        return getattr(m, "value", None) if m is not None else None

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def write_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        return path

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def report(self) -> str:
        """Human-readable fixed-width table of every instrument."""
        snap = self.snapshot()
        if not snap:
            return "(no metrics recorded)"
        w = max(len(n) for n in snap)
        lines = [f"{'metric'.ljust(w)}  type       value"]
        for name, s in snap.items():
            if s["type"] == "histogram":
                if not s["count"]:
                    val = "count=0"
                else:
                    val = (f"count={s['count']} mean={s['mean']:.6g} "
                           f"p50={s['p50']:.6g} p95={s['p95']:.6g} "
                           f"max={s['max']:.6g}")
            else:
                val = f"{s['value']:.6g}"
            lines.append(f"{name.ljust(w)}  {s['type']:<9}  {val}")
        return "\n".join(lines)
