"""Tracer: nestable spans over a thread-safe in-process buffer.

The tracer is the timing half of ``repro.obs`` (the metrics half lives in
``repro.obs.metrics``).  Spans measure *where wall time goes* across the
nugget lifecycle — ``pipeline.run`` > ``stage.profile`` >
``intervals.analyze_batch`` — and export to two sinks:

- **JSONL** (``trace.jsonl``): one event object per line, append-friendly,
  mergeable across processes/hosts (``repro.launch.obs merge``),
- **Chrome trace** (``trace.json``): the ``traceEvents`` format that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly, so a full
  pipeline run can be inspected in a real trace viewer.

Disabled (the default) the tracer is a handful of attribute reads per
``span()`` call — the hot-loop budget is enforced by
``benchmarks/bench_hook_overhead.py`` (<2%% of a training step).  Span
nesting is tracked per thread (``threading.local``); buffer appends take a
lock, so concurrent stages/chunks trace safely.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

# Chrome-trace phases used here: X = complete span, i = instant event,
# M = metadata (process/thread names).
_PH_SPAN = "X"
_PH_INSTANT = "i"


class Span:
    """One open span.  Use as a context manager (``with tracer.span(...)``);
    ``event()`` records instants inside it, ``set()`` attaches attributes
    that land in the Chrome-trace ``args`` dict."""

    __slots__ = ("tracer", "name", "attrs", "t0", "_tid", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self._tid = 0
        self._depth = 0

    # -- context manager ----------------------------------------------
    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        self._tid = threading.get_ident()
        self._depth = self.tracer._push()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        self.tracer._pop()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        worker = self.tracer.worker()
        if worker is not None:
            self.attrs.setdefault("worker", worker)
        self.tracer._emit({
            "ph": _PH_SPAN, "name": self.name, "cat": "span",
            "ts": self.tracer._us(self.t0), "dur": int((t1 - self.t0) * 1e6),
            "pid": self.tracer.pid, "tid": self._tid,
            "args": self.attrs,
        })

    # -- span API ------------------------------------------------------
    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs: Any) -> None:
        self.tracer._emit({
            "ph": _PH_INSTANT, "name": f"{self.name}.{name}", "cat": "event",
            "ts": self.tracer._us(time.perf_counter()), "s": "t",
            "pid": self.tracer.pid, "tid": threading.get_ident(),
            "args": attrs,
        })


class _NullSpan:
    """Disabled-path span: every operation is a no-op.  A single shared
    instance is returned for all ``span()`` calls while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs: Any) -> None:
        return None


NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe in-process trace buffer with JSONL/Chrome-trace sinks.

    ``enabled=False`` (default): ``span()`` returns the shared
    :data:`NULL_SPAN` without allocating; ``event()`` returns immediately.
    A ``sink`` path makes every emit also append a JSONL line (crash-safe:
    the buffer-only mode loses events on a hard crash, the sink does not).
    """

    def __init__(self, enabled: bool = False, sink: Optional[str] = None,
                 process_name: str = "repro"):
        self.enabled = enabled
        self.pid = os.getpid()
        self.process_name = process_name
        self._epoch = time.perf_counter()
        self._buf: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._sink_path = sink
        self._sink_file = None
        # worker tagging: per-thread logical worker names (set by pool
        # schedulers) so concurrent spans render as named tracks
        self._thread_names: Dict[int, str] = {}
        if sink:
            os.makedirs(os.path.dirname(os.path.abspath(sink)), exist_ok=True)
            self._sink_file = open(sink, "a")

    # -- internals -----------------------------------------------------
    def _us(self, t: float) -> int:
        return int((t - self._epoch) * 1e6)

    def _push(self) -> int:
        d = getattr(self._local, "depth", 0)
        self._local.depth = d + 1
        return d

    def _pop(self) -> None:
        self._local.depth = max(0, getattr(self._local, "depth", 1) - 1)

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._buf.append(ev)
            if self._sink_file is not None:
                self._sink_file.write(json.dumps(ev) + "\n")
                self._sink_file.flush()

    # -- worker tagging ------------------------------------------------
    def set_worker(self, name: Optional[str]) -> None:
        """Tag the calling thread with a logical worker name.  Every span
        and event the thread emits afterwards carries a ``worker`` attr,
        and the Chrome-trace export names the thread's track after it."""
        self._local.worker = name
        if name is not None:
            with self._lock:
                self._thread_names[threading.get_ident()] = name

    def worker(self) -> Optional[str]:
        """The calling thread's worker name (None when untagged)."""
        return getattr(self._local, "worker", None)

    # -- public API ----------------------------------------------------
    def span(self, name: str, **attrs: Any):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        worker = self.worker()
        if worker is not None:
            attrs.setdefault("worker", worker)
        self._emit({
            "ph": _PH_INSTANT, "name": name, "cat": "event",
            "ts": self._us(time.perf_counter()), "s": "t",
            "pid": self.pid, "tid": threading.get_ident(),
            "args": attrs,
        })

    def depth(self) -> int:
        """Current span nesting depth on the calling thread."""
        return getattr(self._local, "depth", 0)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def close(self) -> None:
        with self._lock:
            if self._sink_file is not None:
                self._sink_file.close()
                self._sink_file = None

    # -- export --------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The buffer as a Chrome-trace / Perfetto ``traceEvents`` doc."""
        return chrome_trace(self.events(), process_name=self.process_name,
                            pid=self.pid)

    def write_chrome(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return path

    def write_jsonl(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev) + "\n")
        return path


def chrome_trace(events: List[Dict[str, Any]], *, process_name: str = "repro",
                 pid: Optional[int] = None) -> Dict[str, Any]:
    """Wrap raw events into a Chrome-trace document, prepending process
    metadata so the viewer shows a named track.  Threads whose events
    carry a ``worker`` attr (scheduler pool threads) additionally get
    ``thread_name`` metadata, so a merged multi-worker trace renders the
    parallel timeline as named worker tracks."""
    meta: List[Dict[str, Any]] = []
    pids = sorted({ev.get("pid", 0) for ev in events} | ({pid} - {None}))
    for p in pids:
        meta.append({"ph": "M", "name": "process_name", "pid": p, "tid": 0,
                     "args": {"name": f"{process_name}:{p}"}})
    workers: Dict[tuple, str] = {}
    for ev in events:
        w = (ev.get("args") or {}).get("worker")
        if w and "tid" in ev:
            workers[(ev.get("pid", 0), ev["tid"])] = w
    for (p, t), w in sorted(workers.items(), key=lambda kv: str(kv[0])):
        meta.append({"ph": "M", "name": "thread_name", "pid": p, "tid": t,
                     "args": {"name": str(w)}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def read_events(path: str) -> List[Dict[str, Any]]:
    """Load events from a ``trace.jsonl`` or a Chrome ``trace.json`` file
    (metadata records are dropped so merges do not duplicate them)."""
    with open(path) as f:
        text = f.read()
    try:                                      # chrome trace document...
        doc = json.loads(text)
        if isinstance(doc, dict) and "traceEvents" in doc:
            evs = doc["traceEvents"]
        elif isinstance(doc, list):
            evs = doc                         # bare traceEvents array
        else:
            evs = [doc]                       # single-line jsonl
    except json.JSONDecodeError:              # ...else jsonl, one per line
        evs = [json.loads(line) for line in text.splitlines() if line.strip()]
    return [ev for ev in evs if ev.get("ph") != "M"]


def span_summary(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Aggregate complete-span events by name: count, total/mean/max ms."""
    agg: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("ph") != _PH_SPAN:
            continue
        a = agg.setdefault(ev["name"], {"name": ev["name"], "count": 0,
                                        "total_ms": 0.0, "max_ms": 0.0})
        dur_ms = ev.get("dur", 0) / 1e3
        a["count"] += 1
        a["total_ms"] += dur_ms
        a["max_ms"] = max(a["max_ms"], dur_ms)
    out = sorted(agg.values(), key=lambda a: -a["total_ms"])
    for a in out:
        a["mean_ms"] = a["total_ms"] / max(a["count"], 1)
    return out
