"""``repro.obs`` — unified tracing + metrics across the nugget lifecycle.

Zero-dependency observability with three pieces (see
``docs/observability.md``):

- :mod:`repro.obs.trace`   — nestable spans, JSONL sink, Chrome-trace export,
- :mod:`repro.obs.metrics` — counters / gauges / histograms + snapshots,
- :mod:`repro.obs.log`     — structured ``key=value`` logging
  (``REPRO_LOG_LEVEL``).

Module-level singletons keep instrumentation one import away from any hot
loop::

    from repro import obs
    with obs.span("stage.profile", key=digest) as sp:
        ...
        sp.event("cache_miss")
    obs.metrics().count("store.miss")

Tracing is **disabled by default** — ``obs.span()`` then returns a shared
no-op span (budgeted <2%% of a training step by
``benchmarks/bench_hook_overhead.py``).  Enable per process with
``obs.configure(trace=True, trace_dir=...)`` or the ``REPRO_TRACE`` env var
(``1`` to buffer in memory, a path to also stream JSONL there).
"""
from __future__ import annotations

import os
from typing import Any, Optional

from repro.obs import log  # noqa: F401  (re-exported module)
from repro.obs.metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry,
)
from repro.obs.trace import (  # noqa: F401
    NULL_SPAN, Span, Tracer, chrome_trace, read_events, span_summary,
)

ENV_TRACE = "REPRO_TRACE"

_tracer = Tracer(enabled=False)
_metrics = MetricsRegistry()


# -- accessors ---------------------------------------------------------
def tracer() -> Tracer:
    return _tracer


def metrics() -> MetricsRegistry:
    return _metrics


def span(name: str, **attrs: Any):
    """Open a span on the process tracer (no-op singleton when disabled)."""
    t = _tracer
    if not t.enabled:
        return NULL_SPAN
    return t.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    t = _tracer
    if t.enabled:
        t.event(name, **attrs)


def enabled() -> bool:
    return _tracer.enabled


def set_worker(name: Optional[str]) -> None:
    """Tag the calling thread with a logical worker name (scheduler pools
    call this); subsequent spans/events carry it as a ``worker`` attr."""
    _tracer.set_worker(name)


# -- configuration -----------------------------------------------------
def configure(*, trace: Optional[bool] = None,
              trace_dir: Optional[str] = None,
              reset_metrics: bool = False) -> Tracer:
    """(Re)configure process-wide observability.

    ``trace=True`` swaps in a fresh enabled tracer; with ``trace_dir`` its
    events also stream to ``<trace_dir>/trace.jsonl`` as they happen.
    ``trace=False`` swaps back to a disabled tracer.  Returns the active
    tracer either way.
    """
    global _tracer
    if trace is not None:
        _tracer.close()
        sink = (os.path.join(trace_dir, "trace.jsonl")
                if (trace and trace_dir) else None)
        _tracer = Tracer(enabled=bool(trace), sink=sink)
    if reset_metrics:
        _metrics.reset()
    return _tracer


def configure_from_env() -> Tracer:
    """Honor ``REPRO_TRACE``: unset/``0``/empty = disabled, ``1`` = buffer
    in memory, any other value = treat as a directory and stream JSONL."""
    raw = os.environ.get(ENV_TRACE, "").strip()
    if raw in ("", "0", "false"):
        return configure(trace=False)
    if raw in ("1", "true"):
        return configure(trace=True)
    return configure(trace=True, trace_dir=raw)
