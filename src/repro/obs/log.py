"""Structured logging for the repro CLIs and libraries.

One ``setup()`` replaces the per-launcher ``logging.basicConfig`` /
``print`` mix with a single handler emitting structured ``key=value``
lines::

    ts=2026-08-08T12:00:01.123 level=info logger=repro.launch.pipeline \
        event=manifest_written path=/tmp/manifest.json

Level resolution order: explicit ``level`` argument, then the
``REPRO_LOG_LEVEL`` environment variable (``debug``/``info``/``warning``/
``error`` or a numeric level), then ``info``.  ``kv()`` is the logging
helper call sites use: an event name plus keyword fields, rendered in
stable order.
"""
from __future__ import annotations

import logging
import os
import sys
import time
from typing import Any, Optional

ENV_LEVEL = "REPRO_LOG_LEVEL"
_ROOT = "repro"


def _quote(v: Any) -> str:
    s = str(v)
    if any(c in s for c in ' "='):
        return '"' + s.replace('"', r'\"') + '"'
    return s


class KVFormatter(logging.Formatter):
    """``key=value`` line formatter; extra fields come via ``kv()``."""

    def format(self, record: logging.LogRecord) -> str:
        ts = time.strftime("%Y-%m-%dT%H:%M:%S", time.localtime(record.created))
        parts = [f"ts={ts}.{int(record.msecs):03d}",
                 f"level={record.levelname.lower()}",
                 f"logger={record.name}"]
        fields = getattr(record, "kv_fields", None)
        if fields is not None:
            parts.append(f"event={record.getMessage()}")
            parts.extend(f"{k}={_quote(v)}" for k, v in fields.items())
        else:
            parts.append(f"msg={_quote(record.getMessage())}")
        if record.exc_info:
            parts.append(f"exc={_quote(self.formatException(record.exc_info))}")
        return " ".join(parts)


def resolve_level(level: Optional[str] = None) -> int:
    raw = level if level is not None else os.environ.get(ENV_LEVEL, "info")
    if isinstance(raw, int):
        return raw
    raw = str(raw).strip()
    if raw.isdigit():
        return int(raw)
    resolved = logging.getLevelName(raw.upper())
    return resolved if isinstance(resolved, int) else logging.INFO


def setup(level: Optional[str] = None, stream=None) -> logging.Logger:
    """Install one KV-formatted handler on the ``repro`` logger (idempotent:
    re-running replaces the handler, so repeated CLI invocations in one
    process never double-log)."""
    root = logging.getLogger(_ROOT)
    root.setLevel(resolve_level(level))
    for h in list(root.handlers):
        if getattr(h, "_repro_kv", False):
            root.removeHandler(h)
    handler = logging.StreamHandler(stream if stream is not None
                                    else sys.stderr)
    handler.setFormatter(KVFormatter())
    handler._repro_kv = True
    root.addHandler(handler)
    root.propagate = False
    return root


def get_logger(name: str = _ROOT) -> logging.Logger:
    if name != _ROOT and not name.startswith(_ROOT + "."):
        name = f"{_ROOT}.{name}"
    return logging.getLogger(name)


def kv(event: str, *, logger: str = _ROOT, level: int = logging.INFO,
       **fields: Any) -> None:
    """Log one structured event: ``kv("cache_hit", kind="profile", ...)``."""
    get_logger(logger).log(level, event, extra={"kv_fields": fields})
