"""Artifact-driven sampling pipeline: the paper's profile -> select ->
mark -> replay -> validate lifecycle as composable typed stages over a
content-addressed :class:`ArtifactStore` (see ``docs/pipeline.md``)."""
from repro.pipeline.store import (  # noqa: F401
    ARTIFACT_KINDS, Artifact, ArtifactStore, artifact_key, canonical_json,
    persist_profile_cli,
)
from repro.pipeline.stages import (  # noqa: F401
    BaselineStage, MarkStage, ProfileStage, ReplayStage, SelectStage, Stage,
    ValidateStage,
)
from repro.pipeline.runtime import (  # noqa: F401
    Pipeline, PipelineConfig, PipelineContext, platform_config,
)
from repro.pipeline.journal import RunJournal  # noqa: F401
from repro.pipeline.scheduler import run_dag  # noqa: F401
from repro.faults import (  # noqa: F401  (shared failure vocabulary)
    FaultInjector, RetryPolicy,
)
