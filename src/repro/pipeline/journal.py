"""Append-only run journal for crash-resume.

One JSONL file per (store, logical run config) records the lifecycle of
every pipeline execution against that config: ``run_start``,
``stage_start`` / ``stage_commit`` per stage, ``run_end``.  Each line is
flushed and fsync'd as it is written, so a SIGKILL'd run leaves a
faithful prefix — the rerun reads it to report which stages were
already committed (``resumed_stages`` in the manifest) before the
content-addressed store turns them into plain cache hits.

The journal is *advisory*: resume correctness comes from the store's
atomic commits (``spec.json`` last), not from the journal.  A torn
final line (the crash landed mid-write) is skipped on read.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List


class RunJournal:
    """Thread-safe append-only JSONL event log."""

    def __init__(self, path: str):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a")

    def append(self, kind: str, **fields: Any) -> None:
        rec = {"kind": kind, "t": round(time.time(), 3), **fields}
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()
            os.fsync(self._f.fileno())

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self) -> "RunJournal":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- reading -------------------------------------------------------
    @staticmethod
    def read(path: str) -> List[Dict[str, Any]]:
        """Events in file order; unparsable (torn) lines are dropped."""
        if not os.path.exists(path):
            return []
        out: List[Dict[str, Any]] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except ValueError:
                    continue
        return out

    @staticmethod
    def committed(events: List[Dict[str, Any]]) -> Dict[str, str]:
        """stage name -> artifact key for every recorded commit (last
        commit wins when a stage re-ran)."""
        return {e["stage"]: e.get("key", "")
                for e in events if e.get("kind") == "stage_commit"}
