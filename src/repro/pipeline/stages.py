"""Typed pipeline stages with a uniform ``Stage.run(ctx) -> Artifact``
contract (paper Fig. 1 lifecycle, one stage per box):

    ProfileStage   instrumented run -> interval Profile
    SelectStage    selection methodology -> Selection
    MarkStage      marker planning + warmup -> [Nugget]
    BaselineStage  full-run ground truth per platform (validation input)
    ReplayStage    native nugget replay per platform -> [ReplayResult]
    ValidateStage  prediction/speedup error + consistency -> report dict

``run`` resolves the stage's content address from its resolved config
(``spec``) plus the keys of its upstream artifacts, loads the payload on a
hit, computes-and-commits on a miss, and records a manifest entry either
way.  Stages therefore resume: changing only the selector re-runs
selection and everything downstream of it while profile and baseline
artifacts hit the cache.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

from repro import obs
from repro.core.nugget import Nugget, create_nuggets
from repro.core.replay import ReplayEngine, ReplayResult
from repro.core.select import SELECTORS, Selection
from repro.core.validate import full_run_baseline, validation_report
from repro.pipeline.store import Artifact, ArtifactStore


class Stage:
    """One pipeline step.  Subclasses define ``kind``, ``spec``,
    ``deps``, ``compute`` and the payload codec (``save``/``load``).

    ``deps`` names the upstream *stages* this one consumes; it is both
    the edge list the concurrent DAG scheduler executes and the source
    of ``upstream`` (the consumed artifact *keys* that chain into this
    stage's content address) — one declaration, two uses, so the
    scheduler can never run a stage before the artifacts its key
    depends on exist.
    """

    kind: str = ""
    name: str = ""

    # -- to override ---------------------------------------------------
    def spec(self, ctx) -> Dict:
        raise NotImplementedError

    def deps(self, ctx) -> List[str]:
        """Names of the stages whose artifacts this stage consumes."""
        return []

    def compute(self, ctx) -> Any:
        raise NotImplementedError

    # -- derived -------------------------------------------------------
    def upstream(self, ctx) -> List[str]:
        return [ctx.key(name) for name in self.deps(ctx)]

    def save(self, store: ArtifactStore, art: Artifact, payload: Any) -> None:
        raise NotImplementedError

    def load(self, store: ArtifactStore, art: Artifact) -> Any:
        raise NotImplementedError

    # -- uniform driver ------------------------------------------------
    def run(self, ctx) -> Artifact:
        t0 = time.perf_counter()
        journal = getattr(ctx, "journal_event", None)
        with obs.span(f"stage.{self.name}", kind=self.kind) as sp:
            art = ctx.store.resolve(self.kind, self.spec(ctx),
                                    self.upstream(ctx))
            if journal is not None:
                journal("stage_start", stage=self.name,
                        artifact_kind=self.kind, key=art.key)
            # single-flight: concurrent stages (or pipelines) resolving
            # the same key serialize here — one computes, the rest load.
            # ``lookup`` = exists + payload verification: a corrupt
            # artifact is quarantined and recomputed as a plain miss.
            with ctx.store.single_flight(art.key):
                hit = ctx.store.lookup(art)
                if hit:
                    with obs.span(f"stage.{self.name}.load"):
                        payload = self.load(ctx.store, art)
                else:
                    with obs.span(f"stage.{self.name}.compute"):
                        payload = self.compute(ctx)
                    with obs.span(f"stage.{self.name}.save"):
                        self.save(ctx.store, art, payload)
                        ctx.store.commit(art)
            if journal is not None:
                journal("stage_commit", stage=self.name, key=art.key,
                        cache_hit=hit)
            sp.set(key=art.key, cache_hit=hit,
                   upstream=[k[:12] for k in art.upstream])
        wall = time.perf_counter() - t0
        obs.metrics().observe(f"pipeline.stage_s.{self.kind}", wall)
        obs.metrics().count(f"pipeline.{'hits' if hit else 'misses'}")
        ctx.record(self, art, payload, hit, wall)
        return art


class ProfileStage(Stage):
    """Instrumented run on the profile platform -> interval Profile."""

    kind = "profile"
    name = "profile"

    def spec(self, ctx) -> Dict:
        cfg = ctx.cfg
        return {**cfg.platform_spec(cfg.profile_platform_name),
                "steps": cfg.steps, "interval_steps": cfg.interval_steps}

    def compute(self, ctx):
        tr = ctx.trainer(ctx.cfg.profile_platform_name)
        tr.run(ctx.cfg.steps)
        # sharded finalize: with a worker pool the deferred step log is
        # split into chunks, analyzed concurrently and merged in stream
        # order — bit-for-bit identical to the serial profile
        return tr.profile(max_workers=ctx.workers or None)

    def save(self, store, art, payload):
        store.write_profile(art, payload)

    def load(self, store, art):
        return store.read_profile(art)


class SelectStage(Stage):
    kind = "selection"
    name = "select"

    def spec(self, ctx) -> Dict:
        return {"selector": ctx.cfg.selector,
                "args": dict(sorted(ctx.cfg.selector_args.items()))}

    def deps(self, ctx):
        return ["profile"]

    def compute(self, ctx):
        sel_cls = SELECTORS[ctx.cfg.selector]
        return sel_cls(**ctx.cfg.selector_args).select(ctx.payload("profile"))

    def save(self, store, art, payload):
        store.write_json(art, "selection.json", payload.to_json())

    def load(self, store, art):
        return Selection.from_json(store.read_json(art, "selection.json"))


class MarkStage(Stage):
    kind = "nuggets"
    name = "mark"

    def spec(self, ctx) -> Dict:
        cfg = ctx.cfg
        return {"warmup_intervals": cfg.warmup_intervals,
                "search_distance": cfg.search_distance,
                "ckpt_every": cfg.ckpt_every}

    def deps(self, ctx):
        return ["profile", "select"]

    def compute(self, ctx):
        cfg = ctx.cfg
        return create_nuggets(ctx.payload("profile"), ctx.payload("select"),
                              warmup_intervals=cfg.warmup_intervals,
                              search_distance=cfg.search_distance,
                              ckpt_every=cfg.ckpt_every)

    def save(self, store, art, payload):
        store.write_json(art, "nuggets.json",
                         {"nuggets": [n.to_json() for n in payload]})

    def load(self, store, art):
        d = store.read_json(art, "nuggets.json")
        return [Nugget.from_json(n) for n in d["nuggets"]]


class BaselineStage(Stage):
    """Full-run ground-truth wall time for one platform.  Depends only on
    the platform + run shape, never on the selection — so changing the
    selector reuses cached baselines."""

    kind = "baseline"

    def __init__(self, platform: str):
        self.platform = platform
        self.name = f"baseline@{platform}"

    def spec(self, ctx) -> Dict:
        return {**ctx.cfg.platform_spec(self.platform), "steps": ctx.cfg.steps}

    def compute(self, ctx):
        return full_run_baseline(ctx.runner(self.platform), ctx.cfg.steps)

    def save(self, store, art, payload):
        store.write_json(art, "baseline.json", payload)

    def load(self, store, art):
        return store.read_json(art, "baseline.json")


class ReplayStage(Stage):
    """Native nugget replay on one platform -> [ReplayResult]."""

    kind = "replay"

    def __init__(self, platform: str):
        self.platform = platform
        self.name = f"replay@{platform}"

    def spec(self, ctx) -> Dict:
        return ctx.cfg.platform_spec(self.platform)

    def deps(self, ctx):
        return ["profile", "mark"]

    def compute(self, ctx):
        eng = ReplayEngine(ctx.runner(self.platform), ctx.payload("profile"))
        return eng.replay_all(ctx.payload("mark"))

    def save(self, store, art, payload):
        store.write_json(art, "replay.json",
                         {"platform": self.platform,
                          "results": [r.to_json() for r in payload]})

    def load(self, store, art):
        d = store.read_json(art, "replay.json")
        return [ReplayResult.from_json(r) for r in d["results"]]


class ValidateStage(Stage):
    kind = "validation"
    name = "validate"

    def spec(self, ctx) -> Dict:
        return {"platforms": list(ctx.cfg.platforms)}

    def deps(self, ctx):
        names = ["profile", "mark"]
        for p in ctx.cfg.platforms:
            names.append(f"replay@{p}")
            names.append(f"baseline@{p}")
        return names

    def compute(self, ctx):
        results_by = {p: ctx.payload(f"replay@{p}") for p in ctx.cfg.platforms}
        baselines = {p: ctx.payload(f"baseline@{p}")
                     for p in ctx.cfg.platforms}
        return validation_report(ctx.payload("profile"), results_by, baselines)

    def save(self, store, art, payload):
        store.write_json(art, "validation.json", payload)

    def load(self, store, art):
        return store.read_json(art, "validation.json")
