"""Pipeline orchestration: config, context (lazy per-platform trainers),
stage graph and the JSON run manifest.

A *platform* is named by a token parsed into config overrides, e.g.
``f32``, ``bf16-chunk16``, ``f32-ref`` — the same dtype/impl axes the
benchmarks use as stand-ins for distinct machines.  The profile is taken
on ``profile_platform`` (default: the first platform); replay + baseline
run on every platform; validation summarizes across them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Union

from repro import obs
from repro.configs import get_config, reduced
from repro.configs.base import ArchConfig
from repro.faults import FaultInjector, RetryPolicy
from repro.pipeline.journal import RunJournal
from repro.pipeline.scheduler import run_dag
from repro.pipeline.stages import (BaselineStage, MarkStage, ProfileStage,
                                   ReplayStage, SelectStage, Stage,
                                   ValidateStage)
from repro.pipeline.store import (ARTIFACT_KINDS, Artifact, ArtifactStore,
                                  canonical_json)


def platform_config(base: ArchConfig, token: str) -> ArchConfig:
    """Apply a platform token's overrides: dash-separated parts out of
    {f32, bf16, f16, ref, chunk<N>} (e.g. ``bf16-chunk16``, ``f32-ref``)."""
    changes: Dict[str, Any] = {}
    for part in token.split("-"):
        if part in ("f32", "fp32", "float32"):
            changes["compute_dtype"] = "float32"
        elif part in ("bf16", "bfloat16"):
            changes["compute_dtype"] = "bfloat16"
        elif part in ("f16", "float16"):
            changes["compute_dtype"] = "float16"
        elif part == "ref":
            changes["attention_impl"] = "reference"
        elif part.startswith("chunk"):
            changes["attn_chunk"] = int(part[len("chunk"):])
        else:
            raise ValueError(f"unknown platform token part {part!r} "
                             f"in {token!r}")
    return dataclasses.replace(base, **changes)


# PipelineConfig fields that shape execution, not results: excluded from
# stage specs (artifact keys) and the journal run key
EXEC_FIELDS = frozenset({"workers", "max_attempts", "retry_backoff_s",
                         "stage_timeout_s", "gc_orphans"})


@dataclasses.dataclass
class PipelineConfig:
    arch: str
    platforms: Sequence[str] = ("f32", "bf16")
    selector: str = "kmeans"
    selector_args: Dict[str, Any] = dataclasses.field(default_factory=dict)
    steps: int = 32
    seq_len: int = 32
    batch: int = 4
    interval_steps: float = 2.5
    seed: int = 0
    reduce: bool = True
    warmup_intervals: int = 1
    search_distance: float = 0.0
    ckpt_every: int = 0
    defer_analysis: bool = True          # batch (vectorized) interval analysis
    profile_platform: Optional[str] = None   # default: platforms[0]
    # -- execution-only knobs (EXEC_FIELDS): how the run executes, never
    # what it computes.  Excluded from every stage spec AND from the run
    # journal key, so serial/parallel/retried runs share artifact keys
    # and resume each other's journals.
    # stage-scheduler worker threads: 0/1 = the legacy serial loop, N>1 =
    # concurrent DAG execution + sharded profile finalize.
    workers: int = 0
    # stage retry policy (see repro.faults.RetryPolicy): transient
    # failures retry with exponential backoff + deterministic jitter;
    # stage_timeout_s bounds each attempt's wall clock (None = no bound)
    max_attempts: int = 3
    retry_backoff_s: float = 0.05
    stage_timeout_s: Optional[float] = None
    # remove orphaned (uncommitted) artifact dirs at run start — crash
    # debris from a SIGKILL'd run; disable when other pipelines may be
    # computing into the same store concurrently
    gc_orphans: bool = True

    @property
    def profile_platform_name(self) -> str:
        return self.profile_platform or self.platforms[0]

    def retry_policy(self) -> RetryPolicy:
        return RetryPolicy(max_attempts=self.max_attempts,
                           backoff_s=self.retry_backoff_s,
                           timeout_s=self.stage_timeout_s)

    def run_key(self) -> str:
        """Digest identifying the *logical* run (everything except the
        EXEC_FIELDS) — names the journal file, so a crashed serial run
        and its parallel rerun append to the same history."""
        doc = {k: v for k, v in dataclasses.asdict(self).items()
               if k not in EXEC_FIELDS}
        return hashlib.sha256(canonical_json(doc).encode()).hexdigest()[:16]

    def base_cfg(self) -> ArchConfig:
        cfg = get_config(self.arch)
        return reduced(cfg, seq=self.seq_len) if self.reduce else cfg

    def arch_for(self, platform: str) -> ArchConfig:
        return platform_config(self.base_cfg(), platform)

    def platform_spec(self, platform: str) -> Dict:
        """Everything a platform run depends on (part of stage specs)."""
        return {"arch": dataclasses.asdict(self.arch_for(platform)),
                "platform": platform, "seq_len": self.seq_len,
                "batch": self.batch, "seed": self.seed}


class PipelineContext:
    """Per-run state stages see: config, store, produced artifacts/payloads,
    manifest entries, and lazily constructed per-platform trainers (a cache
    hit upstream means the corresponding trainer is never even built).

    Thread-safe: the DAG scheduler runs stages concurrently, so artifact
    and manifest recording take a context lock and trainer construction is
    serialized per platform (two platforms build concurrently; two stages
    of one platform share a single build)."""

    def __init__(self, cfg: PipelineConfig, store: ArtifactStore,
                 workers: int = 0, journal: Optional[RunJournal] = None):
        self.cfg = cfg
        self.store = store
        self.workers = workers
        self.journal = journal
        self.artifacts: Dict[str, Artifact] = {}
        self.payloads: Dict[str, Any] = {}
        self.manifest: List[Dict] = []
        self._trainers: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self._trainer_locks: Dict[str, threading.Lock] = {}

    def journal_event(self, kind: str, **fields: Any) -> None:
        """Append one lifecycle event to the run journal (no-op when the
        run is not journaled — e.g. bare Stage.run in tests)."""
        if self.journal is not None:
            self.journal.append(kind, **fields)

    # -- artifact accessors (stage name -> product) --------------------
    def key(self, name: str) -> str:
        return self.artifacts[name].key

    def payload(self, name: str) -> Any:
        return self.payloads[name]

    def record(self, stage: Stage, art: Artifact, payload: Any,
               hit: bool, wall_s: float) -> None:
        with self._lock:
            self.artifacts[stage.name] = art
            self.payloads[stage.name] = payload
            self.manifest.append({"stage": stage.name, "kind": stage.kind,
                                  "key": art.key, "cache_hit": hit,
                                  "wall_s": wall_s, "path": art.path})

    # -- platforms -----------------------------------------------------
    def trainer(self, platform: str):
        """Lazy Trainer per platform.  Only the profile platform is
        instrumented; replay/baseline platforms use the plain step fn."""
        with self._lock:
            tr = self._trainers.get(platform)
            if tr is not None:
                return tr
            lock = self._trainer_locks.setdefault(platform, threading.Lock())
        with lock:
            if platform not in self._trainers:
                from repro.train import Trainer
                cfg = self.cfg
                tr = Trainer(
                    cfg.arch_for(platform), seq_len=cfg.seq_len,
                    batch=cfg.batch, interval_steps=cfg.interval_steps,
                    seed=cfg.seed,
                    instrument=(platform == cfg.profile_platform_name),
                    defer_analysis=cfg.defer_analysis, donate=False)
                with self._lock:
                    self._trainers[platform] = tr
        return self._trainers[platform]

    def runner(self, platform: str):
        return self.trainer(platform).make_runner()


class Pipeline:
    """The end-to-end nugget lifecycle as a resumable stage graph."""

    def __init__(self, cfg: PipelineConfig,
                 store: Union[str, ArtifactStore],
                 fault_injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.store = (store if isinstance(store, ArtifactStore)
                      else ArtifactStore(store, injector=fault_injector))
        self.injector = fault_injector
        if fault_injector is not None:
            # an injected store also corrupts payloads post-commit
            self.store.injector = fault_injector

    def stages(self) -> List[Stage]:
        out: List[Stage] = [ProfileStage(), SelectStage(), MarkStage()]
        for p in self.cfg.platforms:
            out.append(BaselineStage(p))
        for p in self.cfg.platforms:
            out.append(ReplayStage(p))
        out.append(ValidateStage())
        return out

    def run(self, workers: Optional[int] = None) -> Dict:
        """Run every stage (cache-aware) and return the run manifest.

        With ``workers > 1`` (argument, else ``cfg.workers``) the stage
        graph executes on a concurrent DAG scheduler: every stage whose
        dependencies are complete runs immediately on a worker thread, so
        per-platform baselines/replays and the profile overlap instead of
        serializing.  Stage identity is unaffected — artifact keys, stage
        payloads and the manifest's stage order are identical to a serial
        run; only wall time (and the worker tags on trace spans) differ.

        The manifest embeds an ``obs`` block: the process metrics snapshot
        (store hit/miss/bytes, per-stage wall-time histograms, trainer and
        analyzer metrics) plus whether tracing was live for the run.

        Fault tolerance (see ``docs/robustness.md``): orphaned
        uncommitted artifact dirs are gc'd at run start, every stage
        start/commit is journaled (fsync'd JSONL under
        ``<store>/.journal/``), transient stage failures retry per
        ``cfg.retry_policy()``, and the manifest's ``fault_tolerance``
        block reports retries/timeouts/worker failures/quarantines plus
        the stages a crashed predecessor had already committed
        (``resumed_stages``).
        """
        cfg = self.cfg
        n_workers = cfg.workers if workers is None else workers
        stages = self.stages()
        order = [s.name for s in stages]
        by_name = {s.name: s for s in stages}
        gc_removed = self.store.gc() if cfg.gc_orphans else []
        journal_path = os.path.join(self.store.root, ".journal",
                                    f"run-{cfg.run_key()}.jsonl")
        prior = RunJournal.committed(RunJournal.read(journal_path))
        journal = RunJournal(journal_path)
        ctx = PipelineContext(cfg, self.store, workers=n_workers,
                              journal=journal)
        deps = {s.name: s.deps(ctx) for s in stages}
        injector = self.injector

        def node(name: str) -> None:
            if injector is not None:
                injector.fire("stage", name)
            by_name[name].run(ctx)

        t0 = time.perf_counter()
        journal.append("run_start", pid=os.getpid(), arch=cfg.arch,
                       workers=n_workers, prior_commits=len(prior))
        try:
            with obs.span("pipeline.run", arch=cfg.arch,
                          platforms=list(cfg.platforms),
                          selector=cfg.selector, workers=n_workers):
                stats = run_dag(order, deps, node, max_workers=n_workers,
                                thread_name_prefix="pipe",
                                retry=cfg.retry_policy())
        except BaseException as e:
            journal.append("run_end", status="error",
                           error=type(e).__name__)
            journal.close()
            raise
        journal.append("run_end", status="ok")
        journal.close()
        # stages record completion concurrently; report them in graph
        # declaration order so serial and parallel manifests are comparable
        entries = {e["stage"]: e for e in ctx.manifest}
        manifest = [entries[name] for name in order]
        hits = sum(1 for s in manifest if s["cache_hit"])
        orphans = {k: len(self.store.orphans(k)) for k in ARTIFACT_KINDS}
        return {
            "config": dataclasses.asdict(cfg),
            "store": self.store.root,
            "workers": n_workers,
            "stages": manifest,
            "metrics": ctx.payload("validate"),
            "cache_hits": hits,
            "cache_misses": len(manifest) - hits,
            "wall_s": time.perf_counter() - t0,
            "fault_tolerance": {
                "retries": stats["retries"],
                "timeouts": stats["timeouts"],
                "worker_failures": stats["worker_failures"],
                "fallback_serial": stats["fallback_serial"],
                "quarantined": self.store.counters["quarantined"],
                "journal": journal_path,
                "resumed_stages": sorted(prior),
                "orphans_removed": gc_removed,
                "orphans": {k: n for k, n in orphans.items() if n},
                "faults": (injector.summary()
                           if injector is not None else None),
            },
            "obs": {"traced": obs.enabled(),
                    "store_counters": dict(self.store.counters),
                    "metrics": obs.metrics().snapshot()},
        }
