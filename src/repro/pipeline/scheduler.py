"""Concurrent DAG executor for the stage graph.

``run_dag`` drives a dependency graph of named nodes through a thread
pool: every node whose dependencies are complete is submitted
immediately, so independent branches (per-platform baselines and
replays, profile vs. baseline) overlap instead of serializing.  The
executor is deliberately generic — nodes are names, dependencies are
name lists, and the work is an opaque ``run(name)`` callable — so the
pipeline runtime stays the single place that knows what a stage *is*.

Scheduling is deterministic: ready nodes are submitted in declaration
order, so with ``max_workers=1`` (or ``0``) execution degrades to
exactly the legacy serial loop.  Worker threads tag themselves into the
process tracer (``obs.set_worker``) before running a node, so every
span a stage emits carries the worker id and ``repro.launch.obs``
merge/export renders the parallel timeline as named tracks.

Fault tolerance (``repro.faults`` vocabulary):

- **Retries** — with a :class:`~repro.faults.RetryPolicy`, a node
  attempt that fails with a *transient* error (``classify``) is retried
  up to ``max_attempts`` times with exponential backoff and
  deterministic jitter; retry/timeout events land in the obs trace
  (``stage.retry`` / ``stage.timeout``) and metrics
  (``pipeline.retries`` / ``pipeline.timeouts``).  Fatal errors
  propagate on the first attempt, exactly like the no-policy path.
- **Timeouts** — ``RetryPolicy.timeout_s`` bounds each attempt's wall
  clock: the attempt runs on a watchdog thread and a breach raises
  :class:`~repro.faults.StageTimeout` (transient, so it retries).  The
  stalled attempt is abandoned (daemon thread); because the store's
  commit is idempotent and keyed, a zombie attempt that eventually
  finishes is harmless.
- **Worker-death fallback** — a node that dies with
  :class:`~repro.faults.WorkerKilled` is rescheduled; after
  ``serial_fallback_after`` deaths the pool is drained and the
  remaining graph finishes on the caller's thread (the legacy serial
  loop), logging the downgrade (``scheduler.fallback_serial``) — the
  run completes rather than flaking.

Other failure semantics are unchanged: the first fatal node exception
propagates to the caller; nodes already running finish, nothing new is
scheduled, queued-but-unstarted futures are cancelled; a dependency
cycle raises instead of deadlocking.  ``run_dag`` returns a stats dict
(``retries`` / ``timeouts`` / ``worker_failures`` / ``fallback_serial``)
that the pipeline manifest surfaces.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Set

from repro import obs
from repro.faults import RetryPolicy, StageTimeout, WorkerKilled, classify


def run_dag(order: Sequence[str], deps: Mapping[str, Sequence[str]],
            run: Callable[[str], None], *, max_workers: int = 0,
            thread_name_prefix: str = "worker",
            retry: Optional[RetryPolicy] = None,
            serial_fallback_after: int = 2) -> Dict[str, Any]:
    """Execute every node of a dependency graph, concurrently when possible.

    ``order`` lists all nodes (and fixes the tie-break: among ready nodes,
    earlier declaration runs/submits first).  ``deps[name]`` names the
    nodes that must complete before ``name`` may start.  ``run(name)``
    performs the work; its fatal exceptions propagate.  ``max_workers <=
    1`` runs serially on the calling thread — no pool, no worker tags —
    which keeps the serial path byte-identical to the legacy loop.

    ``retry`` enables transient-error retries and per-attempt timeouts
    (see module docstring); ``serial_fallback_after`` is the number of
    ``WorkerKilled`` casualties after which the remaining graph degrades
    to the serial loop.  Returns the run's fault-tolerance stats.

    Raises ``ValueError`` for unknown/duplicate nodes and ``RuntimeError``
    when the graph has a cycle (detected, not deadlocked).
    """
    names = list(order)
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate node names in {names!r}")
    known = set(names)
    waiting: Dict[str, Set[str]] = {}
    for n in names:
        ds = set(deps.get(n, ()))
        unknown = ds - known
        if unknown:
            raise ValueError(f"node {n!r} depends on unknown {sorted(unknown)}")
        waiting[n] = ds

    stats: Dict[str, Any] = {"retries": 0, "timeouts": 0,
                             "worker_failures": 0, "fallback_serial": False}
    stats_lock = threading.Lock()

    if max_workers <= 1:
        _run_serial(names, waiting, run, retry, stats, stats_lock)
        return stats

    alldeps = {n: set(deps.get(n, ())) for n in names}
    completed: Set[str] = set()
    futs: Dict[cf.Future, str] = {}
    degraded = False
    with cf.ThreadPoolExecutor(max_workers=max_workers,
                               thread_name_prefix=thread_name_prefix) as ex:
        try:
            while waiting or futs:
                ready = [n for n in names
                         if n in waiting and waiting[n] <= completed]
                for n in ready:
                    del waiting[n]
                    futs[ex.submit(_tagged, run, n, retry,
                                   stats, stats_lock)] = n
                if not futs:
                    raise RuntimeError(
                        f"dependency cycle among {sorted(waiting)}")
                done, _ = cf.wait(futs, return_when=cf.FIRST_COMPLETED)
                for f in done:
                    name = futs.pop(f)
                    if _completed_or_requeue(f, name, alldeps, waiting,
                                             stats, stats_lock):
                        completed.add(name)
                    elif stats["worker_failures"] >= serial_fallback_after:
                        degraded = True
                if degraded:
                    # drain in-flight nodes, requeueing further casualties
                    for f, name in list(futs.items()):
                        if _completed_or_requeue(f, name, alldeps, waiting,
                                                 stats, stats_lock):
                            completed.add(name)
                    futs.clear()
                    break
        finally:
            for f in futs:              # queued-but-unstarted work
                f.cancel()
    if degraded and waiting:
        stats["fallback_serial"] = True
        obs.metrics().count("scheduler.fallback_serial")
        obs.event("scheduler.fallback_serial",
                  remaining=len(waiting),
                  worker_failures=stats["worker_failures"])
        obs.log.kv("scheduler_degraded", logger="scheduler",
                   worker_failures=stats["worker_failures"],
                   remaining=sorted(waiting))
        _run_serial(names, waiting, run, retry, stats, stats_lock,
                    completed=completed)
    return stats


def _completed_or_requeue(fut: cf.Future, name: str,
                          alldeps: Mapping[str, Set[str]],
                          waiting: Dict[str, Set[str]],
                          stats: Dict[str, Any],
                          stats_lock: threading.Lock) -> bool:
    """Resolve one finished future: True when the node completed; a
    ``WorkerKilled`` casualty is counted and the node requeued (False);
    any other exception re-raises."""
    try:
        fut.result()
        return True
    except WorkerKilled:
        with stats_lock:
            stats["worker_failures"] += 1
        obs.metrics().count("scheduler.worker_failures")
        obs.event("scheduler.worker_killed", stage=name)
        obs.log.kv("worker_killed", logger="scheduler", stage=name,
                   failures=stats["worker_failures"])
        waiting[name] = set(alldeps[name])
        return False


def _run_serial(names: Sequence[str], waiting: Dict[str, Set[str]],
                run: Callable[[str], None],
                retry: Optional[RetryPolicy] = None,
                stats: Optional[Dict[str, Any]] = None,
                stats_lock: Optional[threading.Lock] = None,
                completed: Optional[Set[str]] = None) -> None:
    completed = set() if completed is None else completed
    while waiting:
        ready = [n for n in names if n in waiting and waiting[n] <= completed]
        if not ready:
            raise RuntimeError(f"dependency cycle among {sorted(waiting)}")
        for n in ready:
            del waiting[n]
            _attempt(run, n, retry, stats, stats_lock, in_worker=False)
            completed.add(n)


def _tagged(run: Callable[[str], None], name: str,
            retry: Optional[RetryPolicy], stats: Optional[Dict[str, Any]],
            stats_lock: Optional[threading.Lock]) -> None:
    """Run one node with the pool thread's worker id on the tracer, so
    every span the node emits is attributable to its worker track."""
    obs.set_worker(threading.current_thread().name)
    _attempt(run, name, retry, stats, stats_lock, in_worker=True)


def _attempt(run: Callable[[str], None], name: str,
             retry: Optional[RetryPolicy], stats: Optional[Dict[str, Any]],
             stats_lock: Optional[threading.Lock], *,
             in_worker: bool) -> None:
    """Drive one node through the retry policy.  ``WorkerKilled`` in a
    pool worker propagates immediately (the scheduler loop reschedules
    the node / degrades to serial); on the caller thread there is no
    worker to lose, so it retries like any transient error."""
    if retry is None:
        run(name)
        return
    attempt = 1
    while True:
        try:
            _bounded(run, name, retry.timeout_s, stats, stats_lock)
            return
        except Exception as e:
            if isinstance(e, WorkerKilled) and in_worker:
                raise
            if classify(e) != "transient" or attempt >= retry.max_attempts:
                raise
            delay = retry.delay(name, attempt)
            if stats_lock is not None:
                with stats_lock:
                    stats["retries"] += 1
            obs.metrics().count("pipeline.retries")
            obs.event("stage.retry", stage=name, attempt=attempt,
                      error=type(e).__name__, delay_s=round(delay, 4))
            obs.log.kv("stage_retry", logger="scheduler", stage=name,
                       attempt=attempt, error=type(e).__name__,
                       delay_s=round(delay, 4))
            time.sleep(delay)
            attempt += 1


def _bounded(run: Callable[[str], None], name: str,
             timeout_s: Optional[float], stats: Optional[Dict[str, Any]],
             stats_lock: Optional[threading.Lock]) -> None:
    """Run one attempt, bounded by ``timeout_s`` on a watchdog thread.
    A breach abandons the attempt (daemon thread) and raises
    ``StageTimeout``; without a timeout the attempt runs inline."""
    if not timeout_s:
        run(name)
        return
    box: Dict[str, Any] = {}
    worker = obs.tracer().worker()

    def target():
        if worker is not None:
            obs.set_worker(worker)
        try:
            run(name)
        except BaseException as e:      # noqa: BLE001 - relayed below
            box["exc"] = e

    th = threading.Thread(target=target, name=f"attempt-{name}", daemon=True)
    th.start()
    th.join(timeout_s)
    if th.is_alive():
        if stats_lock is not None:
            with stats_lock:
                stats["timeouts"] += 1
        obs.metrics().count("pipeline.timeouts")
        obs.event("stage.timeout", stage=name, timeout_s=timeout_s)
        obs.log.kv("stage_timeout", logger="scheduler", stage=name,
                   timeout_s=timeout_s)
        raise StageTimeout(f"stage {name!r} exceeded its "
                           f"{timeout_s}s wall-clock budget")
    if "exc" in box:
        raise box["exc"]
