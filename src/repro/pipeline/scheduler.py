"""Concurrent DAG executor for the stage graph.

``run_dag`` drives a dependency graph of named nodes through a thread
pool: every node whose dependencies are complete is submitted
immediately, so independent branches (per-platform baselines and
replays, profile vs. baseline) overlap instead of serializing.  The
executor is deliberately generic — nodes are names, dependencies are
name lists, and the work is an opaque ``run(name)`` callable — so the
pipeline runtime stays the single place that knows what a stage *is*.

Scheduling is deterministic: ready nodes are submitted in declaration
order, so with ``max_workers=1`` (or ``0``) execution degrades to
exactly the legacy serial loop.  Worker threads tag themselves into the
process tracer (``obs.set_worker``) before running a node, so every
span a stage emits carries the worker id and ``repro.launch.obs``
merge/export renders the parallel timeline as named tracks.

Failure semantics: the first node exception propagates to the caller;
nodes already running are allowed to finish, nothing new is scheduled,
and queued-but-unstarted futures are cancelled.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
from typing import Callable, Dict, Mapping, Sequence, Set

from repro import obs


def run_dag(order: Sequence[str], deps: Mapping[str, Sequence[str]],
            run: Callable[[str], None], *, max_workers: int = 0,
            thread_name_prefix: str = "worker") -> None:
    """Execute every node of a dependency graph, concurrently when possible.

    ``order`` lists all nodes (and fixes the tie-break: among ready nodes,
    earlier declaration runs/submits first).  ``deps[name]`` names the
    nodes that must complete before ``name`` may start.  ``run(name)``
    performs the work; its exceptions propagate.  ``max_workers <= 1``
    runs serially on the calling thread — no pool, no worker tags —
    which keeps the serial path byte-identical to the legacy loop.

    Raises ``ValueError`` for unknown/duplicate nodes and ``RuntimeError``
    when the graph has a cycle (detected, not deadlocked).
    """
    names = list(order)
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate node names in {names!r}")
    known = set(names)
    waiting: Dict[str, Set[str]] = {}
    for n in names:
        ds = set(deps.get(n, ()))
        unknown = ds - known
        if unknown:
            raise ValueError(f"node {n!r} depends on unknown {sorted(unknown)}")
        waiting[n] = ds

    if max_workers <= 1:
        _run_serial(names, waiting, run)
        return

    completed: Set[str] = set()
    futs: Dict[cf.Future, str] = {}
    with cf.ThreadPoolExecutor(max_workers=max_workers,
                               thread_name_prefix=thread_name_prefix) as ex:
        try:
            while waiting or futs:
                ready = [n for n in names
                         if n in waiting and waiting[n] <= completed]
                for n in ready:
                    del waiting[n]
                    futs[ex.submit(_tagged, run, n)] = n
                if not futs:
                    raise RuntimeError(
                        f"dependency cycle among {sorted(waiting)}")
                done, _ = cf.wait(futs, return_when=cf.FIRST_COMPLETED)
                for f in done:
                    name = futs.pop(f)
                    f.result()          # re-raises the node's exception
                    completed.add(name)
        finally:
            for f in futs:              # queued-but-unstarted work
                f.cancel()


def _run_serial(names: Sequence[str], waiting: Dict[str, Set[str]],
                run: Callable[[str], None]) -> None:
    completed: Set[str] = set()
    while waiting:
        ready = [n for n in names if n in waiting and waiting[n] <= completed]
        if not ready:
            raise RuntimeError(f"dependency cycle among {sorted(waiting)}")
        for n in ready:
            del waiting[n]
            run(n)
            completed.add(n)


def _tagged(run: Callable[[str], None], name: str) -> None:
    """Run one node with the pool thread's worker id on the tracer, so
    every span the node emits is attributable to its worker track."""
    obs.set_worker(threading.current_thread().name)
    run(name)
