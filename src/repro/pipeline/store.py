"""Content-addressed artifact store for the sampling pipeline.

Generalizes ``core/profile_store.py`` (which persists only Profiles) to
*every* lifecycle product: profiles, selections, nuggets, replay results,
full-run baselines and validation reports.  Layout::

    <root>/<kind>/<key>/spec.json    # provenance: spec + upstream keys
    <root>/<kind>/<key>/...          # kind-specific payload files

Keys are **input-addressed**: ``key = sha256(kind || upstream keys ||
canonical spec JSON)``.  A stage's spec is everything its computation
depends on (resolved config), and its upstream list is the keys of the
artifacts it consumes — so digests chain through the stage graph exactly
like a build system.  Re-running a pipeline after changing only the
selector changes the selection key (and, transitively, every downstream
key) while the profile and baseline keys — which do not consume the
selection — stay put and hit the cache.

``spec.json`` is written last, atomically (write + ``os.replace``); its
presence marks the artifact complete, so a crashed run never leaves a
half-written directory that later loads as a hit.  At commit the sha256
of every payload file is recorded in ``spec.json`` (``files``); every
cache-hit ``lookup`` re-hashes the payload against it, and a mismatch
quarantines the artifact (moved to ``<root>/.quarantine/``) and reports
a miss so the caller transparently recomputes instead of poisoning the
warm run.  ``orphans`` lists uncommitted (crash-debris) directories and
``gc`` removes them.

The store is concurrency-safe: every key has a per-key re-entrant lock
(``single_flight``) that the stage driver holds across its
check-compute-commit critical section, so two stages (or two pipelines
sharing a store) that resolve the same artifact key compute it exactly
once — the loser of the race blocks, then loads the winner's commit as
a plain cache hit.  ``commit`` takes the same lock and is idempotent:
an already-committed key returns without rewriting ``spec.json``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro import obs
from repro.core.intervals import Profile
from repro.core.profile_store import load_profile, save_profile

ARTIFACT_KINDS = ("profile", "selection", "nuggets", "replay", "baseline",
                  "validation")


def canonical_json(obj: Any) -> str:
    """Deterministic JSON: sorted keys, no whitespace, tuples as lists."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=_jsonable)


def _jsonable(o: Any):
    if dataclasses.is_dataclass(o) and not isinstance(o, type):
        return dataclasses.asdict(o)
    if hasattr(o, "tolist"):
        return o.tolist()
    raise TypeError(f"not canonically serializable: {o!r}")


def artifact_key(kind: str, spec: Dict, upstream: Sequence[str] = ()) -> str:
    """sha256 content address of an artifact: kind + upstream digests + spec."""
    h = hashlib.sha256()
    h.update(kind.encode())
    for k in upstream:
        h.update(b"\x00")
        h.update(k.encode())
    h.update(b"\x01")
    h.update(canonical_json(spec).encode())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Artifact:
    """Handle to one stored pipeline product (payload lives on disk)."""
    kind: str
    key: str
    path: str                      # directory under the store root
    spec: Dict                     # resolved config that produced it
    upstream: List[str]            # keys of consumed artifacts


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(chunk), b""):
            h.update(block)
    return h.hexdigest()


class ArtifactStore:
    """Content-addressed, kind-partitioned on-disk artifact cache.

    ``injector`` (a :class:`repro.faults.FaultInjector`) threads the
    fault-injection harness through the store: its ``corrupt`` rules
    fire right after a commit, which integrity verification must then
    catch on the next cache-hit load.
    """

    QUARANTINE = ".quarantine"

    def __init__(self, root: str, injector: Optional[Any] = None):
        self.root = str(root)
        self.injector = injector
        # per-instance cache accounting, mirrored into the process
        # MetricsRegistry (store.hit / store.miss / store.put_bytes / ...)
        self.counters = {"hit": 0, "miss": 0, "put_bytes": 0,
                         "verified": 0, "verify_s": 0.0, "quarantined": 0}
        self._counters_lock = threading.Lock()
        # per-key re-entrant locks (commit() re-acquires under
        # single_flight()); the registry itself is guarded by _locks_lock
        self._key_locks: Dict[str, threading.RLock] = {}
        self._locks_lock = threading.Lock()

    def _key_lock(self, key: str) -> threading.RLock:
        with self._locks_lock:
            lock = self._key_locks.get(key)
            if lock is None:
                lock = self._key_locks.setdefault(key, threading.RLock())
            return lock

    @contextlib.contextmanager
    def single_flight(self, key: str) -> Iterator[None]:
        """Serialize the check-compute-commit critical section of one key.

        Concurrent holders of the same key queue up; whoever enters first
        computes, everyone after it sees the committed artifact and loads.
        Re-entrant, so ``commit`` may be called while held.
        """
        with self._key_lock(key):
            yield

    def _count(self, name: str, amount: float = 1) -> None:
        with self._counters_lock:
            self.counters[name] += amount

    # -- addressing ----------------------------------------------------
    def path(self, kind: str, key: str) -> str:
        return os.path.join(self.root, kind, key)

    def resolve(self, kind: str, spec: Dict,
                upstream: Sequence[str] = ()) -> Artifact:
        key = artifact_key(kind, spec, upstream)
        return Artifact(kind, key, self.path(kind, key), dict(spec),
                        list(upstream))

    def exists(self, artifact: Artifact) -> bool:
        hit = os.path.exists(os.path.join(artifact.path, "spec.json"))
        self._count("hit" if hit else "miss")
        obs.metrics().count(f"store.{'hit' if hit else 'miss'}")
        if obs.enabled():
            obs.event("store.lookup", kind=artifact.kind,
                      key=artifact.key[:12], hit=hit)
        return hit

    def lookup(self, artifact: Artifact) -> bool:
        """``exists`` plus payload integrity: a committed artifact whose
        payload fails verification is quarantined and reported as a miss,
        so the caller transparently recomputes it."""
        present = os.path.exists(os.path.join(artifact.path, "spec.json"))
        hit = present and self.verify(artifact)
        if present and not hit:
            self.quarantine(artifact)
        self._count("hit" if hit else "miss")
        obs.metrics().count(f"store.{'hit' if hit else 'miss'}")
        if obs.enabled():
            obs.event("store.lookup", kind=artifact.kind,
                      key=artifact.key[:12], hit=hit)
        return hit

    # -- integrity -----------------------------------------------------
    def verify(self, artifact: Artifact) -> bool:
        """Re-hash every payload file against the digests recorded in
        ``spec.json`` at commit.  Artifacts committed before integrity
        recording (no ``files`` entry) pass vacuously."""
        t0 = time.perf_counter()
        try:
            with open(os.path.join(artifact.path, "spec.json")) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return False
        files = doc.get("files")
        ok = True
        if files is not None:
            for rel, want in sorted(files.items()):
                p = os.path.join(artifact.path, rel)
                try:
                    got = _sha256_file(p)
                except OSError:
                    ok = False
                    break
                if got != want:
                    ok = False
                    break
        dt = time.perf_counter() - t0
        self._count("verified")
        self._count("verify_s", dt)
        obs.metrics().count("store.verified")
        obs.metrics().observe("store.verify_s", dt)
        return ok

    def quarantine(self, artifact: Artifact) -> str:
        """Move a corrupt artifact directory under ``<root>/.quarantine``
        (same filesystem, atomic rename) so it can never satisfy another
        cache hit; returns the destination path."""
        qroot = os.path.join(self.root, self.QUARANTINE)
        os.makedirs(qroot, exist_ok=True)
        base = os.path.join(qroot, f"{artifact.kind}-{artifact.key}")
        dest, i = base, 0
        while os.path.exists(dest):
            i += 1
            dest = f"{base}.{i}"
        os.rename(artifact.path, dest)
        self._count("quarantined")
        obs.metrics().count("store.quarantined")
        obs.log.kv("artifact_quarantined", logger="store",
                   kind=artifact.kind, key=artifact.key[:12], dest=dest)
        if obs.enabled():
            obs.event("store.quarantine", kind=artifact.kind,
                      key=artifact.key[:12])
        return dest

    # -- payload IO ----------------------------------------------------
    def write_json(self, artifact: Artifact, name: str, payload: Any) -> None:
        """Atomic payload write: temp file in the artifact dir, then
        ``os.replace`` — the same discipline as ``commit``, so a crash
        mid-write can never leave a torn payload behind an eventual
        completion marker."""
        os.makedirs(artifact.path, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=artifact.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, default=_jsonable)
            os.replace(tmp, os.path.join(artifact.path, name))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def read_json(self, artifact: Artifact, name: str) -> Any:
        with open(os.path.join(artifact.path, name)) as f:
            return json.load(f)

    def write_profile(self, artifact: Artifact, profile: Profile) -> None:
        save_profile(os.path.join(artifact.path, "profile"), profile)

    def read_profile(self, artifact: Artifact) -> Profile:
        return load_profile(os.path.join(artifact.path, "profile"))

    # -- completion marker --------------------------------------------
    def commit(self, artifact: Artifact) -> None:
        """Mark the artifact complete (atomic: spec.json appears last).

        Idempotent under concurrency: the per-key lock serializes racing
        committers and an already-committed key returns without touching
        the directory (or the put counters) again.
        """
        with self._key_lock(artifact.key):
            marker = os.path.join(artifact.path, "spec.json")
            if os.path.exists(marker):      # already committed: fast path
                obs.metrics().count("store.commit_dedup")
                return
            os.makedirs(artifact.path, exist_ok=True)
            # one walk: payload byte count + per-file sha256 (integrity
            # record; hash-on-commit amortizes into the compute miss)
            nbytes = 0
            files: Dict[str, str] = {}
            for d, _, fs in os.walk(artifact.path):
                for fn in fs:
                    p = os.path.join(d, fn)
                    if fn.endswith(".tmp"):
                        continue
                    nbytes += os.path.getsize(p)
                    rel = os.path.relpath(p, artifact.path)
                    files[rel.replace(os.sep, "/")] = _sha256_file(p)
            doc = {"kind": artifact.kind, "key": artifact.key,
                   "spec": artifact.spec, "upstream": artifact.upstream,
                   "files": files}
            fd, tmp = tempfile.mkstemp(dir=artifact.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(doc, f, indent=1, default=_jsonable)
                os.replace(tmp, marker)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            nbytes += os.path.getsize(marker)
            if self.injector is not None:
                # fault harness: corrupt rules land right after the
                # commit so verification must catch them on the next hit
                self.injector.corrupt(artifact.path, artifact.kind)
        self._count("put_bytes", nbytes)
        obs.metrics().count("store.put_bytes", nbytes)
        obs.metrics().count("store.put")

    # -- maintenance ---------------------------------------------------
    def keys(self, kind: str) -> List[str]:
        d = os.path.join(self.root, kind)
        if not os.path.isdir(d):
            return []
        return sorted(k for k in os.listdir(d)
                      if os.path.exists(os.path.join(d, k, "spec.json")))

    def orphans(self, kind: str) -> List[str]:
        """Uncommitted artifact directories (no ``spec.json``): the
        debris a crashed run leaves mid-compute.  ``keys`` silently
        skips them; this makes them visible (the pipeline manifest
        surfaces the counts)."""
        d = os.path.join(self.root, kind)
        if not os.path.isdir(d):
            return []
        return sorted(k for k in os.listdir(d)
                      if os.path.isdir(os.path.join(d, k))
                      and not os.path.exists(os.path.join(d, k, "spec.json")))

    def gc(self, min_age_s: float = 0.0) -> List[str]:
        """Remove orphaned (uncommitted) artifact directories; returns
        ``kind/key`` for each one removed.

        ``min_age_s > 0`` spares directories touched within that window
        — use it when other pipelines may be computing into the same
        store concurrently (their in-flight artifacts are uncommitted
        by design).  The default (0) is the rerun-after-crash posture:
        the pipeline gc's at run start, before any stage computes.
        """
        removed: List[str] = []
        cutoff = time.time() - min_age_s
        for kind in ARTIFACT_KINDS:
            base = os.path.join(self.root, kind)
            for key in self.orphans(kind):
                p = os.path.join(base, key)
                if min_age_s > 0:
                    try:
                        newest = max(
                            [os.path.getmtime(p)] +
                            [os.path.getmtime(os.path.join(d, f))
                             for d, _, fs in os.walk(p) for f in fs])
                    except OSError:
                        continue
                    if newest > cutoff:
                        continue
                shutil.rmtree(p, ignore_errors=True)
                removed.append(f"{kind}/{key}")
        if removed:
            obs.metrics().count("store.gc_removed", len(removed))
            obs.log.kv("store_gc", logger="store", removed=len(removed))
        return removed


def persist_profile_cli(builder, *, profile_out: Optional[str],
                        profile_cache: Optional[str],
                        store: Optional[str], spec: Dict) -> None:
    """Shared profile-persistence tail for the train/serve launchers.

    ``--profile-cache`` keys on the *step stream* (core-level cache);
    ``--store`` keys on the *run spec* (pipeline-level ArtifactStore);
    ``--profile-out`` writes a plain profile directory.
    """
    from repro.core.profile_store import cached_finalize
    if profile_cache:
        prof, hit = cached_finalize(profile_cache, builder)
        obs.log.kv("profile_cache", logger="pipeline",
                   hit=hit, path=profile_cache)
    else:
        prof = builder.finalize()
    if store:
        s = ArtifactStore(store)
        art = s.resolve("profile", spec)
        if not s.exists(art):
            s.write_profile(art, prof)
            s.commit(art)
        obs.log.kv("profile_artifact", logger="pipeline",
                   key=art.key[:12], path=art.path)
    if profile_out:
        save_profile(profile_out, prof)
        obs.log.kv("profile_saved", logger="pipeline", path=profile_out)
