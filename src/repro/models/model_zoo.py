"""Unified model facade: build any assigned architecture, expose
init / loss / forward / prefill / decode plus cache construction and
ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, dtype_of
from repro.distributed.sharding import ShardingPlan, shard
from repro.models import decode as D
from repro.models import encdec as ED
from repro.models import kvcache as KC
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.transformer import ModelDims


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int, *, z_loss: float = 1e-4):
    """Sharded-vocab-safe CE with z-loss.  logits: [B,S,V], labels: [B,S]."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    shifted = lf - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=jnp.float32)
    correct = jnp.sum(lf * onehot, axis=-1)
    nll = lse - correct
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss, nll


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    dims: ModelDims

    # ---- params ----------------------------------------------------------
    def specs(self):
        if self.cfg.family == "encdec":
            specs = ED.encdec_specs(self.cfg, self.dims)
        else:
            specs = T.lm_specs(self.cfg, self.dims)
        if self.cfg.weight_quant in ("int8", "int4"):
            specs = L.quantize_specs(specs, self.cfg.weight_quant)
        return specs

    def init(self, key: jax.Array):
        return L.init_tree(key, self.specs(), dtype_of(self.cfg.param_dtype))

    def axes(self):
        return L.axes_tree(self.specs())

    # ---- forward ---------------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array], *, rng=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ED.encdec_forward(params, cfg, self.dims,
                                     batch["tokens"], batch["frames"])
        return T.lm_forward(params, cfg, self.dims, batch["tokens"],
                            patch_embeds=batch.get("patches"), rng=rng)

    def loss(self, params, batch, *, rng=None):
        logits, aux = self.forward(params, batch, rng=rng)
        loss, nll = cross_entropy(logits, batch["labels"], self.cfg.vocab_size)
        if "router_aux_loss" in aux:
            loss = loss + aux["router_aux_loss"] / max(self.cfg.n_layers, 1)
        aux["nll_mean"] = jnp.mean(nll)
        return loss, aux

    # ---- serving ---------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dt = dtype_of(cfg.compute_dtype)
        kv_pad = self.dims.layout.kv_pad if self.dims.layout else 0
        hd = cfg.attn.head_dim if cfg.attn else 0
        quant = cfg.cache_quant == "int8"
        ssm = None
        if cfg.family in ("ssm", "hybrid"):
            d_inner, nh = S.ssm_dims(cfg)
            ssm = dict(n_layers=cfg.n_layers, n_heads=nh,
                       head_dim=cfg.ssm.head_dim, d_state=cfg.ssm.d_state,
                       d_conv=cfg.ssm.d_conv, conv_dim=S.conv_dim(cfg))
        if cfg.family == "ssm":
            return KC.init_cache(cfg.n_layers, batch, max_seq, 0, 0, dt, ssm=ssm)
        if cfg.family == "hybrid":
            ae, n_groups, _ = T._hybrid_groups(cfg)
            c = KC.init_cache(n_groups, batch, max_seq, kv_pad, hd, dt,
                              ssm=ssm, quant=quant)
            return c
        if cfg.family == "encdec":
            return KC.init_cache(cfg.n_layers, batch, max_seq, kv_pad, hd, dt,
                                 cross_len=cfg.n_frames, quant=quant)
        return KC.init_cache(cfg.n_layers, batch, max_seq, kv_pad, hd, dt,
                             quant=quant)

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ED.encdec_prefill(params, cfg, self.dims, batch["tokens"],
                                     batch["frames"], cache)
        return D.lm_prefill(params, cfg, self.dims, batch["tokens"], cache,
                            patch_embeds=batch.get("patches"))

    def decode_step(self, params, token, cache):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ED.encdec_decode(params, cfg, self.dims, token, cache)
        return D.lm_decode(params, cfg, self.dims, token, cache)

    # ---- dry-run specs ---------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        i32 = jnp.int32
        dt = dtype_of(cfg.compute_dtype)
        if shape.kind in ("train", "prefill"):
            s = shape.seq_len
            out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.family == "encdec":
                out["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), dt)
            if cfg.n_patches:
                out["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
            return out
        # decode: one new token + cache of seq_len
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}

    def cache_specs_struct(self, shape: ShapeConfig) -> Dict[str, Any]:
        cache = jax.eval_shape(lambda: self.init_cache(shape.global_batch,
                                                       shape.seq_len))
        return cache

    def param_count(self, params=None) -> int:
        if params is not None:
            return L.param_count(params)
        return self.cfg.param_count()


def build_model(cfg: ArchConfig, plan: Optional[ShardingPlan] = None) -> Model:
    tp = plan.tp_size if plan is not None else 1
    return Model(cfg, ModelDims.make(cfg, tp))
