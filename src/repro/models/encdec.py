"""Whisper-style encoder-decoder backbone (audio frontend is a STUB:
``input_specs()`` feeds precomputed log-mel frame embeddings, per the
assignment).  LayerNorm + GELU + learned positions, enc self-attn (full),
dec self-attn (causal, cached) + cross-attn (cached K/V from the encoder).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, dtype_of
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import kvcache as KC
from repro.models.attention import HeadLayout
from repro.models.layers import ParamSpec
from repro.models.transformer import ModelDims, _aux_zero


def layernorm_specs(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), "ones"),
            "bias": ParamSpec((d,), ("embed",), "zeros")}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def _xattn_specs(a, d, layout):
    s = A.attention_specs(a, d, layout)
    return s


def _enc_layer_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "attn_norm": layernorm_specs(d),
        "attn": A.attention_specs(cfg.attn, d, dims.layout),
        "mlp_norm": layernorm_specs(d),
        "mlp": L.mlp_specs(d, cfg.d_ff, glu=False),
    }


def _dec_layer_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "attn_norm": layernorm_specs(d),
        "attn": A.attention_specs(cfg.attn, d, dims.layout),
        "xattn_norm": layernorm_specs(d),
        "xattn": _xattn_specs(cfg.attn, d, dims.layout),
        "mlp_norm": layernorm_specs(d),
        "mlp": L.mlp_specs(d, cfg.d_ff, glu=False),
    }


def encdec_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    return {
        "embed": {"embedding": ParamSpec((dims.vocab_pad, cfg.d_model),
                                         ("vocab", "embed"), "normal", 1.0)},
        "dec_pos": ParamSpec((cfg.max_seq_len, cfg.d_model), (None, "embed"),
                             "normal", 0.5),
        "enc_pos": ParamSpec((cfg.n_frames, cfg.d_model), (None, "embed"),
                             "normal", 0.5),
        "enc_layers": L.stack_specs(_enc_layer_specs(cfg, dims), cfg.n_enc_layers),
        "dec_layers": L.stack_specs(_dec_layer_specs(cfg, dims), cfg.n_layers),
        "enc_norm": layernorm_specs(cfg.d_model),
        "final_norm": layernorm_specs(cfg.d_model),
    }


def _self_attn(p, cfg, dims, x, positions, *, causal, dt):
    q, k, v = A.qkv(p, cfg.attn, dims.layout, x, positions, dt, rope=False)
    ctx = A.attend(cfg.attention_impl, q, k, v, positions, positions,
                   dims.layout, causal=causal, window=jnp.int32(-1),
                   q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    return A.out_proj(p, dims.layout, ctx, dt), (k, v)


def encode(params, cfg: ArchConfig, dims: ModelDims, frames) -> jax.Array:
    """frames: [B, n_frames, d_model] stub embeddings."""
    dt = dtype_of(cfg.compute_dtype)
    x = frames.astype(dt) + params["enc_pos"].astype(dt)[None]
    x = shard(x, "batch", "seq", "act_embed")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, p):
        h = layernorm(p["attn_norm"], xc)
        y, _ = _self_attn(p["attn"], cfg, dims, h, positions, causal=False, dt=dt)
        xc = xc + y
        h = layernorm(p["mlp_norm"], xc)
        return xc + L.mlp(p["mlp"], h, "gelu", dt), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(params["enc_norm"], x)


def _cross_kv(p, cfg, dims, enc_out, dt):
    k = A._proj(p["wk"], enc_out, ("batch", None, None, None), dt)
    v = A._proj(p["wv"], enc_out, ("batch", None, None, None), dt)
    if dims.layout.repeat > 1:
        k = jnp.repeat(k, dims.layout.repeat, axis=2)
        v = jnp.repeat(v, dims.layout.repeat, axis=2)
    return k, v


def _cross_attend(p, cfg, dims, x, k, v, dt):
    q = A._proj(p["wq"], x, ("batch", "seq", "act_heads", None), dt)
    b, sq = q.shape[:2]
    sk = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    ctx = A.attend_reference(q, k, v, q_pos, k_pos, dims.layout,
                             causal=False, window=jnp.int32(-1))
    return A.out_proj(p, dims.layout, ctx, dt)


def encdec_forward(params, cfg: ArchConfig, dims: ModelDims, tokens,
                   frames) -> Tuple[jax.Array, Dict]:
    """Training forward: encode frames, decode full target sequence."""
    dt = dtype_of(cfg.compute_dtype)
    enc_out = encode(params, cfg, dims, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed_lookup(params["embed"], tokens, dt)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"].astype(dt), 0, s, 0)[None]
    x = shard(x, "batch", "seq", "act_embed")

    def body(xc, p):
        h = layernorm(p["attn_norm"], xc)
        y, _ = _self_attn(p["attn"], cfg, dims, h, positions, causal=True, dt=dt)
        xc = xc + y
        h = layernorm(p["xattn_norm"], xc)
        k, v = _cross_kv(p["xattn"], cfg, dims, enc_out, dt)
        xc = xc + _cross_attend(p["xattn"], cfg, dims, h, k, v, dt)
        h = layernorm(p["mlp_norm"], xc)
        return xc + L.mlp(p["mlp"], h, "gelu", dt), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(params["final_norm"], x)
    logits = x @ params["embed"]["embedding"].astype(dt).T
    if dims.vocab_pad > cfg.vocab_size:
        mask = jnp.arange(dims.vocab_pad) < cfg.vocab_size
        logits = jnp.where(mask[None, None], logits, -1e30)
    return shard(logits, "batch", "seq", "act_vocab"), _aux_zero(cfg)


def encdec_prefill(params, cfg: ArchConfig, dims: ModelDims, tokens, frames,
                   cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any], Dict]:
    """Encode + run the prompt through the decoder, filling self & cross KV."""
    dt = dtype_of(cfg.compute_dtype)
    enc_out = encode(params, cfg, dims, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed_lookup(params["embed"], tokens, dt)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"].astype(dt), 0, s, 0)[None]

    def body(xc, p):
        h = layernorm(p["attn_norm"], xc)
        y, kv = _self_attn(p["attn"], cfg, dims, h, positions, causal=True, dt=dt)
        xc = xc + y
        h = layernorm(p["xattn_norm"], xc)
        ck, cv = _cross_kv(p["xattn"], cfg, dims, enc_out, dt)
        xc = xc + _cross_attend(p["xattn"], cfg, dims, h, ck, cv, dt)
        h = layernorm(p["mlp_norm"], xc)
        return xc + L.mlp(p["mlp"], h, "gelu", dt), (kv[0], kv[1], ck, cv)

    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["cross_k"], cache["cross_v"] = ck, cv
    cache["length"] = jnp.full_like(cache["length"], s)
    x = layernorm(params["final_norm"], x[:, -1:])
    logits = x @ params["embed"]["embedding"].astype(dt).T
    return logits, KC.shard_cache(cache), _aux_zero(cfg)


def encdec_decode(params, cfg: ArchConfig, dims: ModelDims, token,
                  cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any], Dict]:
    dt = dtype_of(cfg.compute_dtype)
    lengths = cache["length"]
    positions = lengths[:, None]
    x = L.embed_lookup(params["embed"], token, dt)
    x = x + jnp.take(params["dec_pos"].astype(dt), lengths, axis=0)[:, None, :]

    def body(carry, xs):
        xc = carry
        p, k_l, v_l, ck_l, cv_l = xs
        h = layernorm(p["attn_norm"], xc)
        q, k, v = A.qkv(p["attn"], cfg.attn, dims.layout, h, positions, dt,
                        rope=False)
        rows = jnp.arange(k_l.shape[0])
        k_l = k_l.at[rows, lengths].set(k[:, 0].astype(k_l.dtype))
        v_l = v_l.at[rows, lengths].set(v[:, 0].astype(v_l.dtype))
        ctx = A.attend_decode(q, k_l, v_l, lengths + 1, dims.layout,
                              window=jnp.int32(-1))
        xc = xc + A.out_proj(p["attn"], dims.layout, ctx, dt)
        h = layernorm(p["xattn_norm"], xc)
        xc = xc + _cross_attend(p["xattn"], cfg, dims, h, ck_l, cv_l, dt)
        h = layernorm(p["mlp_norm"], xc)
        xc = xc + L.mlp(p["mlp"], h, "gelu", dt)
        return xc, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    cache["k"], cache["v"] = k_new, v_new
    cache["length"] = lengths + 1
    x = layernorm(params["final_norm"], x)
    logits = x @ params["embed"]["embedding"].astype(dt).T
    if dims.vocab_pad > cfg.vocab_size:
        mask = jnp.arange(dims.vocab_pad) < cfg.vocab_size
        logits = jnp.where(mask[None, None], logits, -1e30)
    return logits, KC.shard_cache(cache), _aux_zero(cfg)
