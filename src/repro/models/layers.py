"""Parameter machinery + elementary layers (pure JAX, no flax).

Parameters are plain pytrees (nested dicts of jnp arrays).  Every leaf is
declared through a :class:`ParamSpec` carrying *logical axis names*; a
parallel tree of logical-axes tuples is produced at init and mapped to mesh
``PartitionSpec`` s by :mod:`repro.distributed.sharding` rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, dtype_of

Params = Dict[str, Any]
Axes = Dict[str, Any]

# ---------------------------------------------------------------------------
# Param spec / initialisation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled | custom
    scale: float = 1.0
    init_fn: Optional[Callable[[jax.Array, Tuple[int, ...]], jax.Array]] = None
    dtype: Optional[str] = None   # override model param dtype (int8 quant)

    def instantiate(self, key: jax.Array, dtype) -> jax.Array:
        if self.dtype is not None:
            dtype = jnp.dtype(self.dtype)
        if self.init_fn is not None:
            return self.init_fn(key, self.shape).astype(dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "scaled":
            fan_in = self.shape[0] if self.shape else 1
            std = self.scale / math.sqrt(max(fan_in, 1))
            return (std * jax.random.normal(key, self.shape)).astype(dtype)
        return (self.scale * 0.02 * jax.random.normal(key, self.shape)).astype(dtype)


def init_tree(key: jax.Array, specs: Dict[str, Any], dtype) -> Params:
    """Instantiate a (nested) dict of ParamSpec into arrays."""
    flat, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    leaves = [s.instantiate(k, dtype) for s, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def axes_tree(specs: Dict[str, Any]) -> Axes:
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_specs(specs: Dict[str, Any], n: int, axis_name: str = "layer") -> Dict[str, Any]:
    """Add a leading stacked-layer dimension to every spec (for scanned layers)."""
    def _stack(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape,
                                   axes=(axis_name,) + s.axes)
    return jax.tree.map(_stack, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6,
            *, plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if plus_one:                       # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(dt)


def l2norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt)


# ---------------------------------------------------------------------------
# Projections / embeddings / MLP
# ---------------------------------------------------------------------------


def dense_specs(d_in: int, d_out: int, axes: Tuple[Optional[str], ...],
                *, bias: bool = False, init: str = "scaled",
                scale: float = 1.0) -> Dict[str, ParamSpec]:
    out = {"kernel": ParamSpec((d_in, d_out), axes, init, scale)}
    if bias:
        out["bias"] = ParamSpec((d_out,), (axes[-1],), "zeros")
    return out


def get_kernel(params: Params, compute_dtype) -> jax.Array:
    """Materialize a (possibly int8-quantized) kernel in compute dtype.

    Weight-only quantization (serving): kernels stored as int8 with a
    per-output-channel scale; dequantized on use (on TPU the cast happens
    post-load, so HBM traffic is the int8 bytes)."""
    if "kernel_q" in params:
        q = params["kernel_q"].astype(compute_dtype)
        return q * params["kernel_scale"].astype(compute_dtype)[None]
    return params["kernel"].astype(compute_dtype)


def dense(params: Params, x: jax.Array, compute_dtype=None) -> jax.Array:
    if compute_dtype is None:
        compute_dtype = x.dtype
    k = get_kernel(params, compute_dtype)
    y = x.astype(compute_dtype) @ k
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def _quant_reduce_axis(axes: Tuple[Optional[str], ...]) -> int:
    """Contraction (input) axis of a kernel: axis 0, or 1 when the kernel is
    layer-stacked (leading "layer" axis from stack_specs)."""
    return 1 if (axes and axes[0] == "layer") else 0


def quantize_specs(specs, qdtype: str = "int8"):
    """ParamSpec-tree transform: replace every ``kernel`` spec with an
    int8/int4 payload + per-out-channel scale specs (same logical sharding,
    scale inherits the kernel's non-contracting axes)."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "kernel" and isinstance(v, ParamSpec) \
                        and len(v.shape) >= 2:
                    r = _quant_reduce_axis(v.axes)
                    out["kernel_q"] = dataclasses.replace(
                        v, init="zeros", dtype=qdtype)
                    out["kernel_scale"] = ParamSpec(
                        v.shape[:r] + v.shape[r + 1:],
                        v.axes[:r] + v.axes[r + 1:], "ones", dtype="float32")
                else:
                    out[k] = walk(v)
            return out
        return node
    return walk(specs)


def quantize_params(params, axes=None):
    """Real int8 symmetric per-output-channel quantization of every kernel.
    ``axes`` (the matching logical-axes tree) disambiguates layer-stacked
    kernels; without it the contraction axis is assumed to be 0."""
    def walk(node, anode):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                av = anode.get(k) if isinstance(anode, dict) else None
                if k == "kernel" and hasattr(v, "ndim") and v.ndim >= 2:
                    r = _quant_reduce_axis(av if av is not None else ())
                    w = jnp.asarray(v, jnp.float32)
                    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=r),
                                        1e-8) / 127.0
                    q = jnp.clip(jnp.round(w / jnp.expand_dims(scale, r)),
                                 -127, 127)
                    out["kernel_q"] = q.astype(jnp.int8)
                    out["kernel_scale"] = scale
                else:
                    out[k] = walk(v, av)
            return out
        return node
    return walk(params, axes)


def embed_specs(vocab: int, d: int) -> Dict[str, ParamSpec]:
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), "normal", 1.0)}


def embed_lookup(params: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    # one-hot matmul keeps the op MXU-friendly AND shardable over "vocab";
    # take() would force a replicated gather of the sharded table.
    emb = params["embedding"]
    return emb.astype(compute_dtype)[tokens]


def unembed(params: Params, x: jax.Array, compute_dtype) -> jax.Array:
    emb = params["embedding"].astype(compute_dtype)
    return x.astype(compute_dtype) @ emb.T


ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_specs(d: int, f: int, *, glu: bool = True) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "wi": dense_specs(d, f, ("embed", "mlp")),
        "wo": dense_specs(f, d, ("mlp", "embed")),
    }
    if glu:
        specs["wg"] = dense_specs(d, f, ("embed", "mlp"))
    return specs


def mlp(params: Params, x: jax.Array, act: str, compute_dtype) -> jax.Array:
    h = dense(params["wi"], x, compute_dtype)
    h = ACTS[act](h)
    if "wg" in params:
        h = h * dense(params["wg"], x, compute_dtype)
    return dense(params["wo"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]                              # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)
