"""Mixture-of-Experts layer: top-k routing, capacity-bounded sorted dispatch,
expert parallelism over the "model" mesh axis.

Dispatch is *per batch row* (buffers [B, E, C, d]): each (data, model) device
multiplies its local tokens against its local experts, so no all-to-all is
required — the only collectives are the contraction psums XLA already inserts
for tensor parallelism.  Router statistics (tokens/expert, dropped tokens) are
returned as dynamic Nugget-signature entries (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.layers import ParamSpec


def moe_specs(cfg: ArchConfig) -> Dict[str, Any]:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    specs: Dict[str, Any] = {
        "router": {"kernel": ParamSpec((d, m.n_experts), ("embed", "experts"),
                                       "scaled")},
        "wi": ParamSpec((m.n_experts, d, fe), ("experts", "embed", "expert_mlp"),
                        "scaled"),
        "wo": ParamSpec((m.n_experts, fe, d), ("experts", "expert_mlp", "embed"),
                        "scaled"),
    }
    if cfg.glu:
        specs["wg"] = ParamSpec((m.n_experts, d, fe),
                                ("experts", "embed", "expert_mlp"), "scaled")
    if m.n_shared_experts:
        specs["shared"] = L.mlp_specs(d, cfg.d_ff, glu=cfg.glu)
    return specs


def capacity(seq_len: int, m: MoEConfig) -> int:
    c = int(math.ceil(seq_len * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)          # pad to 8 for TPU-friendly tiling


def route(router_params, x: jax.Array, m: MoEConfig, rng=None):
    """x: [B,S,d] -> (expert ids [B,S,k], gates [B,S,k], aux dict)."""
    logits = L.dense(router_params, x, jnp.float32)        # [B,S,E]
    if rng is not None and m.router_jitter > 0:
        logits = logits + m.router_jitter * jax.random.normal(rng, logits.shape)
    gates_full = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates_full, m.top_k)      # [B,S,k]
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    T = x.shape[0] * x.shape[1]
    me = jnp.mean(gates_full.reshape(-1, m.n_experts), axis=0)
    onehot = jax.nn.one_hot(top_e[..., 0], m.n_experts, dtype=jnp.float32)
    ce = jnp.mean(onehot.reshape(-1, m.n_experts), axis=0)
    aux_loss = m.n_experts * jnp.sum(me * ce) * m.aux_loss_coef
    return top_e, top_g, {"router_aux_loss": aux_loss, "router_logits_max":
                          jnp.max(jnp.abs(logits))}


def dispatch_indices(top_e: jax.Array, k: int, n_experts: int, cap: int):
    """Per batch row, sorted capacity-bounded slotting.

    top_e: [S, k] expert ids for one row -> (slot [S*k] int32 in [0, E*cap),
    keep [S*k] bool).  Tokens beyond an expert's capacity are dropped
    (standard capacity-factor semantics).
    """
    flat_e = top_e.reshape(-1)                              # [S*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    keep_sorted = pos < cap
    slot_sorted = sorted_e * cap + jnp.minimum(pos, cap - 1)
    # unsort back to (token, k) order
    inv = jnp.argsort(order)
    return slot_sorted[inv].astype(jnp.int32), keep_sorted[inv]


def moe_mlp(params, cfg: ArchConfig, x: jax.Array, *, rng=None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.moe
    b, s, d = x.shape
    cap = capacity(s, m)
    dtype = x.dtype

    top_e, top_g, aux = route(params["router"], x, m, rng)

    slot, keep = jax.vmap(lambda e: dispatch_indices(e, m.top_k, m.n_experts, cap))(top_e)
    # scatter tokens into expert buffers [B, E*cap, d]
    tok = jnp.repeat(x, m.top_k, axis=1)                    # [B, S*k, d]
    buf = jnp.zeros((b, m.n_experts * cap, d), dtype)
    wmask = keep[..., None].astype(dtype)
    buf = jax.vmap(lambda bf, sl, tk, km: bf.at[sl].add(tk * km))(
        buf, slot, tok, wmask)
    buf = buf.reshape(b, m.n_experts, cap, d)
    buf = shard(buf, "batch", "experts", None, None)

    # expert MLPs (grouped matmul; E sharded over "model", B over data)
    wi, wo = params["wi"].astype(dtype), params["wo"].astype(dtype)
    h = jnp.einsum("becd,edf->becf", buf, wi)
    h = L.ACTS[cfg.act](h)
    if "wg" in params:
        h = h * jnp.einsum("becd,edf->becf", buf, params["wg"].astype(dtype))
    out_buf = jnp.einsum("becf,efd->becd", h, wo)
    out_buf = shard(out_buf, "batch", "experts", None, None)
    out_buf = out_buf.reshape(b, m.n_experts * cap, d)

    # gather back + combine with gates
    gathered = jax.vmap(lambda ob, sl: ob[sl])(out_buf, slot)   # [B,S*k,d]
    gathered = gathered * (keep[..., None].astype(dtype) *
                           top_g.reshape(b, -1)[..., None].astype(dtype))
    y = jnp.sum(gathered.reshape(b, s, m.top_k, d), axis=2)

    if m.n_shared_experts:
        y = y + L.mlp(params["shared"], x, cfg.act, dtype)

    # ---- dynamic Nugget-signature entries -------------------------------
    onehot_counts = jnp.zeros((m.n_experts,), jnp.int32).at[top_e.reshape(-1)].add(1)
    aux["expert_tokens"] = onehot_counts                     # [E]
    aux["dropped_tokens"] = jnp.sum(~keep)
    return shard(y, "batch", "seq", "act_embed"), aux
