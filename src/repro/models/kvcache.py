"""KV cache (decoder self-attention) + recurrent SSM state.

Layout: stacked over layers so the decode step scans layers with the cache as
scan xs/ys.  ``k``/``v``: [L, B, S_max, KVp, hd]; SSM state: [L, B, nh, hd, N]
and conv state [L, B, d_conv-1, d_conv_dim].  Sharding: batch over
("pod","data"), heads over "model"; for long-context (batch=1) the sequence
dim is sharded over "data" instead (see ShardingPlan.kv_seq).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

CACHE_AXES = {
    "k": (None, "batch", "kv_seq", "act_heads", None),
    "v": (None, "batch", "kv_seq", "act_heads", None),
    "k_scale": (None, "batch", "kv_seq", "act_heads"),
    "v_scale": (None, "batch", "kv_seq", "act_heads"),
    "cross_k": (None, "batch", None, "act_heads", None),
    "cross_v": (None, "batch", None, "act_heads", None),
    "ssm": (None, "batch", "act_heads", None, None),
    "conv": (None, "batch", None, "ssm_inner"),
    "length": ("batch",),
}


def quantize_kv(x: jax.Array):
    """Per-(token, head) int8 quantization.  x: [..., hd] ->
    (int8 [..., hd], scale [...] bf16 with the /127 folded in)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(m, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def init_cache(n_layers: int, batch: int, max_seq: int, kv_pad: int,
               head_dim: int, dtype, *, ssm: Optional[Dict[str, int]] = None,
               cross_len: int = 0, quant: bool = False) -> Dict[str, Any]:
    cache: Dict[str, Any] = {
        "length": jnp.zeros((batch,), jnp.int32),
    }
    kv_dtype = jnp.int8 if quant else dtype
    if kv_pad:
        cache["k"] = jnp.zeros((n_layers, batch, max_seq, kv_pad, head_dim),
                               kv_dtype)
        cache["v"] = jnp.zeros((n_layers, batch, max_seq, kv_pad, head_dim),
                               kv_dtype)
        if quant:
            cache["k_scale"] = jnp.zeros((n_layers, batch, max_seq, kv_pad),
                                         jnp.bfloat16)
            cache["v_scale"] = jnp.zeros((n_layers, batch, max_seq, kv_pad),
                                         jnp.bfloat16)
    if cross_len and kv_pad:
        cache["cross_k"] = jnp.zeros((n_layers, batch, cross_len, kv_pad, head_dim), dtype)
        cache["cross_v"] = jnp.zeros((n_layers, batch, cross_len, kv_pad, head_dim), dtype)
    if ssm is not None:
        cache["ssm"] = jnp.zeros(
            (ssm["n_layers"], batch, ssm["n_heads"], ssm["head_dim"], ssm["d_state"]),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (ssm["n_layers"], batch, ssm["d_conv"] - 1, ssm["conv_dim"]), dtype)
    return cache


def shard_cache(cache: Dict[str, Any]) -> Dict[str, Any]:
    return {k: shard(v, *CACHE_AXES[k]) for k, v in cache.items()}


def cache_specs(cache: Dict[str, Any], plan) -> Dict[str, Any]:
    return {k: plan.spec(CACHE_AXES[k]) for k in cache}


def update_layer_kv(k_layer: jax.Array, v_layer: jax.Array,
                    k_new: jax.Array, v_new: jax.Array,
                    index: jax.Array):
    """Write k_new/v_new ([B,s,KVp,hd]) at position ``index`` (scalar)."""
    k_layer = jax.lax.dynamic_update_slice(
        k_layer, k_new.astype(k_layer.dtype), (0, index, 0, 0))
    v_layer = jax.lax.dynamic_update_slice(
        v_layer, v_new.astype(v_layer.dtype), (0, index, 0, 0))
    return (shard(k_layer, "batch", "kv_seq", "act_heads", None),
            shard(v_layer, "batch", "kv_seq", "act_heads", None))
