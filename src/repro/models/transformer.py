"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Layers are *scanned* (params stacked on a leading "layer" axis) so the HLO
stays compact for 88-layer archs and remat applies per-layer.  Per-layer
static attention windows (gemma3 5:1 local:global) ride along as scan xs.
Hybrid (zamba2) uses grouped scans with one SHARED attention block between
groups (its params live outside the scan and are reused — paper-faithful to
the released family).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, dtype_of
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.attention import HeadLayout
from repro.models.layers import ParamSpec


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """Mesh-dependent derived dimensions (head/vocab padding)."""
    tp: int
    layout: Optional[HeadLayout]
    vocab_pad: int

    @staticmethod
    def make(cfg: ArchConfig, tp: int) -> "ModelDims":
        layout = HeadLayout.make(cfg.attn, tp) if cfg.attn else None
        vpad = tp * math.ceil(cfg.vocab_size / tp)
        return ModelDims(tp, layout, vpad)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def layer_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        specs["attn_norm"] = L.rmsnorm_specs(d)
        specs["attn"] = A.attention_specs(cfg.attn, d, dims.layout)
        specs["mlp_norm"] = L.rmsnorm_specs(d)
        if cfg.family == "moe":
            specs["moe"] = M.moe_specs(cfg)
        else:
            specs["mlp"] = L.mlp_specs(d, cfg.d_ff, glu=cfg.glu)
    elif cfg.family in ("ssm", "hybrid"):
        specs["ssm_norm"] = L.rmsnorm_specs(d)
        specs["ssm"] = S.mamba2_specs(cfg)
    return specs


def shared_attn_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "norm": L.rmsnorm_specs(d),
        "attn": A.attention_specs(cfg.attn, d, dims.layout),
        "mlp_norm": L.rmsnorm_specs(d),
        "mlp": L.mlp_specs(d, cfg.d_ff, glu=cfg.glu),
    }


def lm_specs(cfg: ArchConfig, dims: ModelDims) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": {"embedding": ParamSpec((dims.vocab_pad, cfg.d_model),
                                         ("vocab", "embed"), "normal", 1.0)},
        "final_norm": L.rmsnorm_specs(cfg.d_model),
    }
    per_layer = layer_specs(cfg, dims)
    if cfg.scan_layers:
        specs["layers"] = L.stack_specs(per_layer, cfg.n_layers)
    else:
        specs["layers"] = {f"layer_{i}": per_layer for i in range(cfg.n_layers)}
    if cfg.family == "hybrid":
        specs["shared_attn"] = shared_attn_specs(cfg, dims)
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"kernel": ParamSpec(
            (cfg.d_model, dims.vocab_pad), ("embed", "vocab"), "scaled")}
    if cfg.n_patches:
        specs["patch_proj"] = L.dense_specs(cfg.d_model, cfg.d_model,
                                            ("embed", None))
    return specs


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _attn_block(p, cfg: ArchConfig, dims: ModelDims, x, positions, window,
                *, plus_one: bool, aux: Dict):
    # named_scope labels survive into HLO metadata: the dry-run/profiler
    # locates markers by label with ZERO runtime overhead — the gem5
    # PC-label tracking analogue (paper §III-D2, DESIGN.md §2)
    with jax.named_scope("nugget_block_attn"):
        h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps, plus_one=plus_one)
        dt = x.dtype
        q, k, v = A.qkv(p["attn"], cfg.attn, dims.layout, h, positions, dt)
        ctx = A.attend(cfg.attention_impl, q, k, v, positions, positions,
                       dims.layout, causal=True, window=window,
                       cap=cfg.attn.softcap, q_chunk=cfg.attn_chunk,
                       kv_chunk=cfg.attn_chunk,
                       causal_skip=cfg.attn_causal_skip)
        return x + A.out_proj(p["attn"], dims.layout, ctx, dt), (k, v)


def _mlp_block(p, cfg, x, *, plus_one: bool, aux: Dict, rng=None):
    scope = "nugget_block_moe" if "moe" in p else "nugget_block_mlp"
    with jax.named_scope(scope):
        h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps, plus_one=plus_one)
        if "moe" in p:
            y, moe_aux = M.moe_mlp(p["moe"], cfg, h, rng=rng)
            for key, val in moe_aux.items():
                aux[key] = aux.get(key, 0) + val
        else:
            y = L.mlp(p["mlp"], h, cfg.act, x.dtype)
            y = shard(y, "batch", "seq", "act_embed")
        return x + y


def dense_layer(p, cfg, dims, x, positions, window, *, plus_one=False,
                aux=None, rng=None):
    aux = {} if aux is None else aux
    if cfg.parallel_block:
        # PaLM-style parallel residual: y = x + attn(n1(x)) + mlp(n2(x)).
        # The two TP partial outputs are summed BEFORE the residual add, so
        # XLA's all-reduce reassociation emits ONE all-reduce per layer
        # instead of two (§Perf lever; halves TP collective bytes).
        h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps, plus_one=plus_one)
        dt = x.dtype
        q, k, v = A.qkv(p["attn"], cfg.attn, dims.layout, h, positions, dt)
        ctx = A.attend(cfg.attention_impl, q, k, v, positions, positions,
                       dims.layout, causal=True, window=window,
                       cap=cfg.attn.softcap, q_chunk=cfg.attn_chunk,
                       kv_chunk=cfg.attn_chunk,
                       causal_skip=cfg.attn_causal_skip)
        attn_out = A.out_proj(p["attn"], dims.layout, ctx, dt)
        h2 = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps, plus_one=plus_one)
        if "moe" in p:
            y, moe_aux = M.moe_mlp(p["moe"], cfg, h2, rng=rng)
            for key, val in moe_aux.items():
                aux[key] = aux.get(key, 0) + val
        else:
            y = L.mlp(p["mlp"], h2, cfg.act, dt)
        x = x + (attn_out + y)
        return shard(x, "batch", "seq", "act_embed"), (k, v), aux
    x, kv = _attn_block(p, cfg, dims, x, positions, window,
                        plus_one=plus_one, aux=aux)
    x = _mlp_block(p, cfg, x, plus_one=plus_one, aux=aux, rng=rng)
    return x, kv, aux


def ssm_layer(p, cfg, x, *, aux=None):
    aux = {} if aux is None else aux
    with jax.named_scope("nugget_block_mamba"):
        h = L.rmsnorm(p["ssm_norm"], x, cfg.norm_eps)
        return x + S.mamba2_block(p["ssm"], cfg, h), aux


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "selective":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _aux_zero(cfg: ArchConfig):
    aux = {}
    if cfg.family == "moe":
        aux["router_aux_loss"] = jnp.zeros((), jnp.float32)
        aux["router_logits_max"] = jnp.zeros((), jnp.float32)
        aux["expert_tokens"] = jnp.zeros((cfg.moe.n_experts,), jnp.int32)
        aux["dropped_tokens"] = jnp.zeros((), jnp.int32)
    return aux


def decoder_stack(params, cfg: ArchConfig, dims: ModelDims, x, positions,
                  *, collect_kv: bool = False, rng=None, plus_one=False):
    """Run all layers full-sequence.  Returns (x, aux, kv or None)."""
    windows = jnp.asarray(cfg.layer_windows() or [0], jnp.int32)
    aux0 = _aux_zero(cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            xc, aux = carry
            p, win, key = xs
            aux = dict(aux)
            xc, kv, aux = dense_layer(p, cfg, dims, xc, positions, win,
                                      plus_one=plus_one, aux=aux, rng=key)
            return (xc, aux), (kv if collect_kv else None)
        keys = (jax.random.split(rng, cfg.n_layers) if rng is not None
                else jnp.zeros((cfg.n_layers, 2), jnp.uint32))
        g = cfg.remat_group
        if cfg.scan_layers and g > 1 and cfg.n_layers % g == 0 \
                and not collect_kv:
            # remat GROUPS of g layers: the bwd stash holds one residual per
            # group instead of per layer, letting the microbatch count (and
            # with it the FSDP weight-regather traffic) drop by ~g (§Perf).
            def gbody(carry, xs):
                xc, aux = carry
                ps, wins, ks = xs
                for i in range(g):
                    aux = dict(aux)
                    xc, _, aux = dense_layer(
                        jax.tree.map(lambda a: a[i], ps), cfg, dims, xc,
                        positions, wins[i], plus_one=plus_one, aux=aux,
                        rng=ks[i])
                return (xc, aux), None
            gbody = _maybe_remat(gbody, cfg)
            grouped = jax.tree.map(
                lambda a: a.reshape(cfg.n_layers // g, g, *a.shape[1:]),
                params["layers"])
            (x, aux), kv = jax.lax.scan(
                gbody, (x, aux0),
                (grouped, windows.reshape(-1, g), keys.reshape(-1, g, 2)))
            return x, aux, None
        body = _maybe_remat(body, cfg)
        if cfg.scan_layers:
            (x, aux), kv = jax.lax.scan(
                body, (x, aux0), (params["layers"], windows, keys))
        else:
            kvs = []
            aux = aux0
            for i in range(cfg.n_layers):
                (x, aux), kv_i = body((x, aux),
                                      (params["layers"][f"layer_{i}"],
                                       windows[i], keys[i]))
                kvs.append(kv_i)
            kv = (jax.tree.map(lambda *a: jnp.stack(a), *kvs)
                  if collect_kv else None)
        return x, aux, kv

    if cfg.family == "ssm":
        def body(carry, p):
            xc, aux = carry
            xc, aux = ssm_layer(p, cfg, xc, aux=dict(aux))
            return (xc, aux), None
        body = _maybe_remat(body, cfg)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        return x, aux, None

    if cfg.family == "hybrid":
        return _hybrid_stack(params, cfg, dims, x, positions,
                             collect_kv=collect_kv)
    raise ValueError(cfg.family)


def _hybrid_groups(cfg: ArchConfig):
    ae = max(cfg.attn_every, 1)
    n_groups = cfg.n_layers // ae
    remainder = cfg.n_layers - n_groups * ae
    return ae, n_groups, remainder


def _shared_attn_block(params, cfg, dims, x, positions, *, cache_kv=None,
                       cache_len=None, collect_kv=False):
    p = params["shared_attn"]
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    dt = x.dtype
    q, k, v = A.qkv(p["attn"], cfg.attn, dims.layout, h, positions, dt)
    win = jnp.int32(-1)
    if cache_kv is not None:
        kc, vc = cache_kv
        ctx = A.attend_decode(q, kc, vc, cache_len, dims.layout, window=win)
    else:
        ctx = A.attend(cfg.attention_impl, q, k, v, positions, positions,
                       dims.layout, causal=True, window=win,
                       q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    x = x + A.out_proj(p["attn"], dims.layout, ctx, dt)
    h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + shard(L.mlp(p["mlp"], h, cfg.act, dt), "batch", "seq", "act_embed")
    return x, (k, v) if collect_kv else None


def _hybrid_stack(params, cfg, dims, x, positions, *, collect_kv=False):
    ae, n_groups, rem = _hybrid_groups(cfg)
    aux = _aux_zero(cfg)

    def ssm_body(carry, p):
        xc = carry
        xc, _ = ssm_layer(p, cfg, xc)
        return xc, None
    ssm_body = _maybe_remat(ssm_body, cfg)

    kvs = []
    for g in range(n_groups):
        sl = jax.tree.map(lambda a: a[g * ae:(g + 1) * ae], params["layers"])
        x, _ = jax.lax.scan(ssm_body, x, sl)
        x, kv = _shared_attn_block(params, cfg, dims, x, positions,
                                   collect_kv=collect_kv)
        kvs.append(kv)
    if rem:
        sl = jax.tree.map(lambda a: a[n_groups * ae:], params["layers"])
        x, _ = jax.lax.scan(ssm_body, x, sl)
    kv = (jax.tree.map(lambda *a: jnp.stack(a), *kvs) if collect_kv else None)
    return x, aux, kv


# ---------------------------------------------------------------------------
# Top-level model
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ArchConfig, dims: ModelDims, tokens,
                 patch_embeds=None):
    dt = dtype_of(cfg.compute_dtype)
    x = L.embed_lookup(params["embed"], tokens, dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if cfg.n_patches and patch_embeds is not None:
        pe = L.dense(params["patch_proj"], patch_embeds.astype(dt), dt)
        x = jnp.concatenate([pe, x[:, cfg.n_patches:]], axis=1) \
            if x.shape[1] > cfg.n_patches else pe[:, :x.shape[1]]
    return shard(x, "batch", "seq", "act_embed")


def unembed(params, cfg: ArchConfig, dims: ModelDims, x):
    dt = dtype_of(cfg.compute_dtype)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, dt)
    else:
        logits = L.dense(params["lm_head"], x, dt)
    logits = shard(logits, "batch", "seq", "act_vocab")
    if dims.vocab_pad > cfg.vocab_size:
        mask = (jnp.arange(dims.vocab_pad) < cfg.vocab_size)
        logits = jnp.where(mask[None, None], logits, -1e30)
    return logits


def lm_forward(params, cfg: ArchConfig, dims: ModelDims, tokens,
               *, patch_embeds=None, rng=None) -> Tuple[jax.Array, Dict]:
    """Training/prefill forward over full sequences -> (logits, aux)."""
    plus_one = cfg.name.startswith("gemma")
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(params, cfg, dims, tokens, patch_embeds)
    x, aux, _ = decoder_stack(params, cfg, dims, x, positions, rng=rng,
                              plus_one=plus_one)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, plus_one=plus_one)
    return unembed(params, cfg, dims, x), aux
