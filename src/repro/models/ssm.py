"""Mamba2 (state-space duality) block: chunked SSD scan, reference recurrence,
single-token decode.  Heads are sharded over the "model" axis (48/64 heads on
the assigned archs — divisible by the 16-way TP axis); B/C projections are
group-shared (1 group) and replicated.

The chunked form computes intra-chunk attention-like matmuls on the MXU plus
an inter-chunk state recurrence (lax.scan over chunks) — the TPU-native
adaptation of the CUDA SSD kernel; the Pallas kernel in
``repro.kernels.ssd`` implements the intra-chunk tile.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.layers import ParamSpec


def ssm_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def mamba2_specs(cfg: ArchConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nh = ssm_dims(cfg)

    def a_init(key, shape):
        lo, hi = s.a_init_range
        u = jax.random.uniform(key, shape, minval=lo, maxval=hi)
        return jnp.log(u)

    return {
        "wz": {"kernel": ParamSpec((d, d_inner), ("embed", "ssm_inner"), "scaled")},
        "wx": {"kernel": ParamSpec((d, d_inner), ("embed", "ssm_inner"), "scaled")},
        "wB": {"kernel": ParamSpec((d, s.d_state), ("embed", None), "scaled")},
        "wC": {"kernel": ParamSpec((d, s.d_state), ("embed", None), "scaled")},
        "wdt": {"kernel": ParamSpec((d, nh), ("embed", "heads"), "scaled")},
        "dt_bias": ParamSpec((nh,), ("heads",), "zeros"),
        "A_log": ParamSpec((nh,), ("heads",), init_fn=a_init),
        "D": ParamSpec((nh,), ("heads",), "ones"),
        "conv_x": ParamSpec((s.d_conv, d_inner), (None, "ssm_inner"), "scaled"),
        "conv_B": ParamSpec((s.d_conv, s.d_state), (None, None), "scaled"),
        "conv_C": ParamSpec((s.d_conv, s.d_state), (None, None), "scaled"),
        "norm": L.rmsnorm_specs(d_inner),
        "wo": {"kernel": ParamSpec((d_inner, d), ("ssm_inner", "embed"), "scaled")},
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv via shifted adds.  x: [B,S,C], w: [K,C].

    Returns (y, new_state) where state is the trailing K-1 inputs (decode).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y), new_state


def _project(params, cfg, x, dtype, conv_state=None):
    s = cfg.ssm
    d_inner, nh = ssm_dims(cfg)
    z = L.dense(params["wz"], x, dtype)
    xin = L.dense(params["wx"], x, dtype)
    Bp = L.dense(params["wB"], x, dtype)
    Cp = L.dense(params["wC"], x, dtype)
    dt = L.dense(params["wdt"], x, jnp.float32)
    if conv_state is None:
        xin, st_x = _causal_conv(xin, params["conv_x"].astype(dtype))
        Bp, st_B = _causal_conv(Bp, params["conv_B"].astype(dtype))
        Cp, st_C = _causal_conv(Cp, params["conv_C"].astype(dtype))
    else:
        cx, cB, cC = conv_state
        xin, st_x = _causal_conv(xin, params["conv_x"].astype(dtype), cx)
        Bp, st_B = _causal_conv(Bp, params["conv_B"].astype(dtype), cB)
        Cp, st_C = _causal_conv(Cp, params["conv_C"].astype(dtype), cC)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    xin = shard(xin.reshape(*xin.shape[:-1], nh, s.head_dim),
                "batch", "seq", "act_heads", None)
    return z, xin, Bp, Cp, dt, (st_x, st_B, st_C)


def _finish(params, cfg, y, xh, dt_unused, z, dtype):
    d_inner, nh = ssm_dims(cfg)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:-2], d_inner).astype(dtype)
    y = y * jax.nn.silu(z)
    y = L.rmsnorm(params["norm"], y, cfg.norm_eps)
    return shard(L.dense(params["wo"], y, dtype), "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Chunked SSD forward
# ---------------------------------------------------------------------------


def ssd_chunked(xh, dt, A, Bp, Cp, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD.  xh: [B,S,nh,hp]; dt: [B,S,nh] (f32); A: [nh] (<0);
    Bp/Cp: [B,S,N].  Returns (y [B,S,nh,hp] f32, h_final [B,nh,hp,N] f32)."""
    b, s, nh, hp = xh.shape
    n = Bp.shape[-1]
    q = min(chunk, s)
    nchunk = -(-s // q)
    pad = nchunk * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0)))

    xf = xh.astype(jnp.float32).reshape(b, nchunk, q, nh, hp)
    dtc = dt.reshape(b, nchunk, q, nh)
    Bc = Bp.astype(jnp.float32).reshape(b, nchunk, q, n)
    Cc = Cp.astype(jnp.float32).reshape(b, nchunk, q, n)
    la = dtc * A[None, None, None, :]                  # log decay per step
    cum = jnp.cumsum(la, axis=2)                       # [b,c,q,nh]

    def chunk_step(h, xs):
        xq, dq, bq, cq, cumq = xs                      # per-chunk slices
        xdtq = xq * dq[..., None]                      # [b,q,nh,hp]
        # intra-chunk: masked decay kernel L[t,s] = exp(cum_t - cum_s), t>=s
        rel = cumq[:, :, None, :] - cumq[:, None, :, :]            # [b,q,q,nh]
        tri = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: exp of the (large-positive) masked upper triangle
        # would poison gradients through jnp.where.
        rel = jnp.where(tri[None, :, :, None], rel, -jnp.inf)
        Lk = jnp.exp(rel)
        cb = jnp.einsum("btn,bsn->bts", cq, bq)                    # [b,q,q]
        y_intra = jnp.einsum("btsh,bts,bshp->bthp", Lk, cb, xdtq)
        # inter-chunk contribution from incoming state
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cq, h,
                             jnp.exp(cumq))
        # state update: S_c = sum_s exp(cum_last - cum_s) B_s xdt_s
        decay_out = jnp.exp(cumq[:, -1:, :] - cumq)                # [b,q,nh]
        s_new = jnp.einsum("bsn,bsh,bshp->bhpn", bq, decay_out, xdtq)
        h = jnp.exp(cumq[:, -1])[:, :, None, None] * h + s_new
        return h, y_intra + y_inter

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32) if h0 is None else h0
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
          jnp.moveaxis(cum, 1, 0))
    h_fin, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nchunk * q, nh, hp)
    return y[:, :s], h_fin


def ssd_reference(xh, dt, A, Bp, Cp):
    """Step-by-step recurrence oracle (f32)."""
    b, s, nh, hp = xh.shape
    n = Bp.shape[-1]

    def step(h, xs):
        xt, dtt, bt, ct = xs
        a = jnp.exp(dtt * A[None])                         # [b,nh]
        dx = xt * dtt[..., None]                           # [b,nh,hp]
        h = a[..., None, None] * h + jnp.einsum("bhp,bn->bhpn", dx, bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32)
    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bp.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cp.astype(jnp.float32), 1, 0))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_fin


# ---------------------------------------------------------------------------
# Block-level entry points
# ---------------------------------------------------------------------------


def mamba2_block(params, cfg: ArchConfig, x: jax.Array, *,
                 impl: Optional[str] = None) -> jax.Array:
    """Full-sequence forward.  x: [B,S,d] -> [B,S,d]."""
    dtype = x.dtype
    s = cfg.ssm
    z, xh, Bp, Cp, dt, _ = _project(params, cfg, x, dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    impl = impl or cfg.ssm_impl
    if impl == "reference":
        y, _ = ssd_reference(xh, dt, A, Bp, Cp)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xh, dt, A, Bp, Cp, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bp, Cp, s.chunk)
    return _finish(params, cfg, y, xh, dt, z, dtype)


def mamba2_decode(params, cfg: ArchConfig, x: jax.Array,
                  ssm_state: jax.Array, conv_state: Tuple[jax.Array, ...]
                  ) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, ...]]:
    """Single-token decode.  x: [B,1,d]; ssm_state: [B,nh,hp,N] (f32)."""
    dtype = x.dtype
    z, xh, Bp, Cp, dt, new_conv = _project(params, cfg, x, dtype, conv_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0] * A[None])                        # [B,nh]
    dx = xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None]
    h = (a[..., None, None] * ssm_state
         + jnp.einsum("bhp,bn->bhpn", dx, Bp[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h, Cp[:, 0].astype(jnp.float32))[:, None]
    out = _finish(params, cfg, y, xh, dt, z, dtype)
    return out, h, new_conv


def conv_dim(cfg: ArchConfig) -> int:
    d_inner, _ = ssm_dims(cfg)
    return d_inner + 2 * cfg.ssm.d_state
