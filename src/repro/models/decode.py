"""Prefill and single-token decode over stacked KV / SSM caches.

Decode scans layers with the per-layer cache slice as scan xs and the updated
slice as scan ys; cache writes are per-row scatters so continuous batching
(per-row lengths) works.  For ``long_500k`` the cache sequence dim is sharded
over "data" and the masked softmax in ``attend_decode`` auto-partitions into
flash-decode partials (see DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, dtype_of
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import kvcache as KC
from repro.models.transformer import (
    ModelDims, _aux_zero, _hybrid_groups, _shared_attn_block, dense_layer,
    embed_tokens, ssm_layer, unembed,
)


def _split_conv(cfg: ArchConfig, conv: jax.Array):
    d_inner, _ = S.ssm_dims(cfg)
    n = cfg.ssm.d_state
    return (conv[..., :d_inner], conv[..., d_inner:d_inner + n],
            conv[..., d_inner + n:])


def _merge_conv(parts) -> jax.Array:
    return jnp.concatenate(parts, axis=-1)


def _write_kv(k_l, v_l, k_new, v_new, lengths):
    """Per-row scatter write of one token's kv at each row's length."""
    b = k_l.shape[0]
    rows = jnp.arange(b)
    k_l = k_l.at[rows, lengths].set(k_new[:, 0].astype(k_l.dtype))
    v_l = v_l.at[rows, lengths].set(v_new[:, 0].astype(v_l.dtype))
    return (shard(k_l, "batch", "kv_seq", "act_heads", None),
            shard(v_l, "batch", "kv_seq", "act_heads", None))


def _write_kv_quant(k_l, v_l, ks_l, vs_l, k_new, v_new, lengths):
    """int8-cache variant: quantize the new token's kv per (row, head)."""
    b = k_l.shape[0]
    rows = jnp.arange(b)
    kq, ks = KC.quantize_kv(k_new[:, 0])
    vq, vs = KC.quantize_kv(v_new[:, 0])
    k_l = k_l.at[rows, lengths].set(kq)
    v_l = v_l.at[rows, lengths].set(vq)
    ks_l = ks_l.at[rows, lengths].set(ks)
    vs_l = vs_l.at[rows, lengths].set(vs)
    return (shard(k_l, "batch", "kv_seq", "act_heads", None),
            shard(v_l, "batch", "kv_seq", "act_heads", None),
            shard(ks_l, "batch", "kv_seq", "act_heads"),
            shard(vs_l, "batch", "kv_seq", "act_heads"))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def lm_prefill(params, cfg: ArchConfig, dims: ModelDims, tokens,
               cache: Dict[str, Any], *, patch_embeds=None
               ) -> Tuple[jax.Array, Dict[str, Any], Dict]:
    """Fill the cache from a full prompt; returns last-position logits."""
    from repro.models.transformer import decoder_stack
    plus_one = cfg.name.startswith("gemma")
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(params, cfg, dims, tokens, patch_embeds)

    if cfg.family in ("dense", "moe", "vlm"):
        x, aux, kv = decoder_stack(params, cfg, dims, x, positions,
                                   collect_kv=True, plus_one=plus_one)
        k, v = kv                                   # [L,B,s,KVp,hd]
        if cfg.cache_quant == "int8":
            kq, ks = KC.quantize_kv(k)
            vq, vs = KC.quantize_kv(v)
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], kq, (0, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], vq, (0, 0, 0, 0, 0))
            cache["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, 0, 0))
            cache["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, 0, 0))
        else:
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    elif cfg.family == "ssm":
        x, aux = _ssm_prefill(params, cfg, x, cache)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_prefill(params, cfg, dims, x, positions, cache)
    else:
        raise ValueError(cfg.family)

    cache["length"] = jnp.full_like(cache["length"], s)
    cache = KC.shard_cache(cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, plus_one=plus_one)
    logits = unembed(params, cfg, dims, x[:, -1:])
    return logits, cache, aux


def _ssm_prefill(params, cfg, x, cache):
    def body(carry, p):
        xc = carry
        h = L.rmsnorm(p["ssm_norm"], xc, cfg.norm_eps)
        dtype = h.dtype
        z, xh, Bp, Cp, dt, conv_st = S._project(p["ssm"], cfg, h, dtype)
        Aa = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
        y, h_fin = S.ssd_chunked(xh, dt, Aa, Bp, Cp, cfg.ssm.chunk)
        out = S._finish(p["ssm"], cfg, y, xh, dt, z, dtype)
        return xc + out, (h_fin, _merge_conv(conv_st))
    (x), (h_all, conv_all) = jax.lax.scan(body, x, params["layers"])
    cache["ssm"] = h_all
    cache["conv"] = conv_all.astype(cache["conv"].dtype)
    return x, _aux_zero(cfg)


def _hybrid_prefill(params, cfg, dims, x, positions, cache):
    ae, n_groups, rem = _hybrid_groups(cfg)

    def body(carry, p):
        xc = carry
        h = L.rmsnorm(p["ssm_norm"], xc, cfg.norm_eps)
        dtype = h.dtype
        z, xh, Bp, Cp, dt, conv_st = S._project(p["ssm"], cfg, h, dtype)
        Aa = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
        y, h_fin = S.ssd_chunked(xh, dt, Aa, Bp, Cp, cfg.ssm.chunk)
        out = S._finish(p["ssm"], cfg, y, xh, dt, z, dtype)
        return xc + out, (h_fin, _merge_conv(conv_st))

    h_states, conv_states, kvs = [], [], []
    for g in range(n_groups):
        sl = jax.tree.map(lambda a: a[g * ae:(g + 1) * ae], params["layers"])
        x, (hs, cs) = jax.lax.scan(body, x, sl)
        h_states.append(hs); conv_states.append(cs)
        x, kv = _shared_attn_block(params, cfg, dims, x, positions,
                                   collect_kv=True)
        kvs.append(kv)
    if rem:
        sl = jax.tree.map(lambda a: a[n_groups * ae:], params["layers"])
        x, (hs, cs) = jax.lax.scan(body, x, sl)
        h_states.append(hs); conv_states.append(cs)

    cache["ssm"] = jnp.concatenate(h_states, axis=0)
    cache["conv"] = jnp.concatenate(conv_states, axis=0).astype(cache["conv"].dtype)
    k = jnp.stack([kv[0] for kv in kvs])
    v = jnp.stack([kv[1] for kv in kvs])
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return x, _aux_zero(cfg)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def lm_decode(params, cfg: ArchConfig, dims: ModelDims, token,
              cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any], Dict]:
    """One decode step.  token: [B,1] int32.  Returns (logits, cache, aux)."""
    plus_one = cfg.name.startswith("gemma")
    lengths = cache["length"]                        # [B]
    positions = lengths[:, None]
    x = embed_tokens(params, cfg, dims, token)
    windows = jnp.asarray(cfg.layer_windows() or [0], jnp.int32)
    aux = _aux_zero(cfg)

    quant = cfg.cache_quant == "int8"
    if quant and cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            "int8 KV cache is implemented for decoder-LM families")

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            xc, aux = carry
            if quant:
                p, win, k_l, v_l, ks_l, vs_l = xs
            else:
                p, win, k_l, v_l = xs
            aux = dict(aux)
            h = L.rmsnorm(p["attn_norm"], xc, cfg.norm_eps, plus_one=plus_one)
            dt = xc.dtype
            q, k, v = A.qkv(p["attn"], cfg.attn, dims.layout, h, positions, dt)
            if quant:
                k_l, v_l, ks_l, vs_l = _write_kv_quant(
                    k_l, v_l, ks_l, vs_l, k, v, lengths)
                k_at = KC.dequantize_kv(k_l, ks_l, dt)
                v_at = KC.dequantize_kv(v_l, vs_l, dt)
            else:
                k_l, v_l = _write_kv(k_l, v_l, k, v, lengths)
                k_at, v_at = k_l, v_l
            ctx = A.attend_decode(q, k_at, v_at, lengths + 1, dims.layout,
                                  window=win, cap=cfg.attn.softcap)
            attn_out = A.out_proj(p["attn"], dims.layout, ctx, dt)
            from repro.models.transformer import _mlp_block
            if cfg.parallel_block:
                # match the parallel-residual training math (one TP AR)
                h2 = L.rmsnorm(p["mlp_norm"], xc, cfg.norm_eps,
                               plus_one=plus_one)
                if "moe" in p:
                    from repro.models import moe as MO
                    y, moe_aux = MO.moe_mlp(p["moe"], cfg, h2)
                    for key, val in moe_aux.items():
                        aux[key] = aux.get(key, 0) + val
                else:
                    y = L.mlp(p["mlp"], h2, cfg.act, dt)
                xc = xc + (attn_out + y)
            else:
                xc = xc + attn_out
                xc = _mlp_block(p, cfg, xc, plus_one=plus_one, aux=aux)
            if quant:
                return (xc, aux), (k_l, v_l, ks_l, vs_l)
            return (xc, aux), (k_l, v_l)
        if quant:
            (x, aux), (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, (x, aux),
                (params["layers"], windows, cache["k"], cache["v"],
                 cache["k_scale"], cache["v_scale"]))
            cache["k"], cache["v"] = k_new, v_new
            cache["k_scale"], cache["v_scale"] = ks_new, vs_new
        else:
            (x, aux), (k_new, v_new) = jax.lax.scan(
                body, (x, aux),
                (params["layers"], windows, cache["k"], cache["v"]))
            cache["k"], cache["v"] = k_new, v_new

    elif cfg.family == "ssm":
        def body(carry, xs):
            xc = carry
            p, h_l, conv_l = xs
            h = L.rmsnorm(p["ssm_norm"], xc, cfg.norm_eps)
            out, h_new, conv_new = S.mamba2_decode(
                p["ssm"], cfg, h, h_l, _split_conv(cfg, conv_l))
            return xc + out, (h_new, _merge_conv(conv_new).astype(conv_l.dtype))
        x, (h_all, conv_all) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv"]))
        cache["ssm"], cache["conv"] = h_all, conv_all

    elif cfg.family == "hybrid":
        ae, n_groups, rem = _hybrid_groups(cfg)

        def body(carry, xs):
            xc = carry
            p, h_l, conv_l = xs
            h = L.rmsnorm(p["ssm_norm"], xc, cfg.norm_eps)
            out, h_new, conv_new = S.mamba2_decode(
                p["ssm"], cfg, h, h_l, _split_conv(cfg, conv_l))
            return xc + out, (h_new, _merge_conv(conv_new).astype(conv_l.dtype))

        h_states, conv_states, k_all, v_all = [], [], [], []
        for g in range(n_groups):
            sl = jax.tree.map(lambda a: a[g * ae:(g + 1) * ae],
                              params["layers"])
            hs = cache["ssm"][g * ae:(g + 1) * ae]
            cs = cache["conv"][g * ae:(g + 1) * ae]
            x, (hn, cn) = jax.lax.scan(body, x, (sl, hs, cs))
            h_states.append(hn); conv_states.append(cn)
            k_l, v_l = cache["k"][g], cache["v"][g]
            p_sh = params["shared_attn"]
            hh = L.rmsnorm(p_sh["norm"], x, cfg.norm_eps)
            q, k, v = A.qkv(p_sh["attn"], cfg.attn, dims.layout, hh,
                            positions, x.dtype)
            k_l, v_l = _write_kv(k_l, v_l, k, v, lengths)
            ctx = A.attend_decode(q, k_l, v_l, lengths + 1, dims.layout,
                                  window=jnp.int32(-1))
            x = x + A.out_proj(p_sh["attn"], dims.layout, ctx, x.dtype)
            hh = L.rmsnorm(p_sh["mlp_norm"], x, cfg.norm_eps)
            x = x + L.mlp(p_sh["mlp"], hh, cfg.act, x.dtype)
            k_all.append(k_l); v_all.append(v_l)
        if rem:
            sl = jax.tree.map(lambda a: a[n_groups * ae:], params["layers"])
            hs = cache["ssm"][n_groups * ae:]
            cs = cache["conv"][n_groups * ae:]
            x, (hn, cn) = jax.lax.scan(body, x, (sl, hs, cs))
            h_states.append(hn); conv_states.append(cn)
        cache["ssm"] = jnp.concatenate(h_states, axis=0)
        cache["conv"] = jnp.concatenate(conv_states, axis=0)
        cache["k"] = jnp.stack(k_all)
        cache["v"] = jnp.stack(v_all)
    else:
        raise ValueError(cfg.family)

    cache["length"] = lengths + 1
    cache = KC.shard_cache(cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, plus_one=plus_one)
    logits = unembed(params, cfg, dims, x)
    return logits, cache, aux
