"""GQA attention: reference (quadratic), chunked (streaming softmax), pallas.

TPU-mesh head padding
---------------------
The production mesh has a 16-way ``model`` axis, but several assigned archs
have head counts not divisible by 16 (llama4/qwen2.5: 40 q heads, 8 kv heads).
JAX rejects uneven input shardings, so the parameter layout pads q heads up to
a multiple of the TP size (pad heads are zero-init and **masked out of the
output**, keeping the math of the assigned arch exact) and expands kv heads by
replication slots (Megatron-style replicated KV for tp > n_kv_heads).  The
FLOP overhead of padding is visible in the roofline MODEL_FLOPS/HLO ratio and
is one of the §Perf levers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.layers import ParamSpec

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    n_heads: int          # real q heads
    n_kv: int             # real kv heads
    h_pad: int            # padded q slots (divisible by tp)
    kv_pad: int           # padded kv slots (divisible by tp, divides h_pad)
    repeat: int           # kv replication factor kv_pad / n_kv
    head_dim: int

    @staticmethod
    def make(a: AttnConfig, tp: int) -> "HeadLayout":
        h, kv = a.n_heads, a.n_kv_heads
        assert h % kv == 0, (h, kv)
        # smallest integer replication r with tp | kv*r (exact kv copies)
        r = tp // math.gcd(kv, tp)
        kv_pad = kv * r
        lcm = tp * kv_pad // math.gcd(tp, kv_pad)
        h_pad = lcm * math.ceil(max(h, 1) / lcm)
        return HeadLayout(h, kv, h_pad, kv_pad, r, a.head_dim)

    @property
    def group(self) -> int:            # q slots per kv slot
        return self.h_pad // self.kv_pad

    @property
    def g_real(self) -> int:           # q slots per REAL kv head
        return self.h_pad // self.n_kv

    def head_mask(self) -> np.ndarray:
        """[h_pad] 1.0 for real q heads, 0.0 for structural padding."""
        real_per_group = self.n_heads // self.n_kv
        s = np.arange(self.h_pad)
        return ((s % self.g_real) < real_per_group).astype(np.float32)

    @property
    def n_pad(self) -> int:
        return self.h_pad - self.n_heads


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_specs(a: AttnConfig, d: int, layout: HeadLayout) -> Dict[str, Any]:
    hd = a.head_dim
    kv_axes = (("embed", "kv_heads", "head_dim") if layout.repeat == 1
               else ("embed", None, None))
    mask = layout.head_mask()

    def q_init(key, shape):
        w = 0.02 * jax.random.normal(key, shape)
        return w * mask[None, :, None]          # zero the pad-head columns

    specs: Dict[str, Any] = {
        "wq": {"kernel": ParamSpec((d, layout.h_pad, hd),
                                   ("embed", "heads", "head_dim"),
                                   init_fn=q_init)},
        "wk": {"kernel": ParamSpec((d, layout.n_kv, hd), kv_axes, "scaled")},
        "wv": {"kernel": ParamSpec((d, layout.n_kv, hd), kv_axes, "scaled")},
        "wo": {"kernel": ParamSpec((layout.h_pad, hd, d),
                                   ("heads", "head_dim", "embed"), "scaled")},
    }
    if a.qkv_bias:
        specs["wq"]["bias"] = ParamSpec((layout.h_pad, hd),
                                        ("heads", "head_dim"), "zeros")
        specs["wk"]["bias"] = ParamSpec((layout.n_kv, hd),
                                        (kv_axes[1], kv_axes[2]), "zeros")
        specs["wv"]["bias"] = ParamSpec((layout.n_kv, hd),
                                        (kv_axes[1], kv_axes[2]), "zeros")
    if a.qk_norm:
        specs["q_norm"] = {"scale": ParamSpec((hd,), (None,), "ones")}
        specs["k_norm"] = {"scale": ParamSpec((hd,), (None,), "ones")}
    return specs


def _proj(p, x, heads_axes, dtype):
    y = jnp.einsum("bsd,dhk->bshk", x.astype(dtype), L.get_kernel(p, dtype))
    if "bias" in p:
        y = y + p["bias"].astype(dtype)
    return shard(y, *heads_axes)


def qkv(params, a: AttnConfig, layout: HeadLayout, x: jax.Array,
        positions: jax.Array, dtype, *, rope: bool = True,
        kv_x: Optional[jax.Array] = None, kv_positions=None):
    """Project to padded-slot q and kv-slot k/v, applying qk-norm + RoPE."""
    kv_x = x if kv_x is None else kv_x
    q = _proj(params["wq"], x, ("batch", "seq", "act_heads", None), dtype)
    k = _proj(params["wk"], kv_x, ("batch", "seq", None, None), dtype)
    v = _proj(params["wv"], kv_x, ("batch", "seq", None, None), dtype)
    if a.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)
    if rope:
        q = L.apply_rope(q, positions, a.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = L.apply_rope(k, kpos, a.rope_theta)
    if layout.repeat > 1:
        k = jnp.repeat(k, layout.repeat, axis=2)
        v = jnp.repeat(v, layout.repeat, axis=2)
    k = shard(k, "batch", "kv_seq", "act_heads", None)
    v = shard(v, "batch", "kv_seq", "act_heads", None)
    return q, k, v


def out_proj(params, layout: HeadLayout, ctx: jax.Array, dtype) -> jax.Array:
    mask = jnp.asarray(layout.head_mask(), dtype)
    ctx = ctx * mask[None, None, :, None]        # kill structural pad heads
    y = jnp.einsum("bshk,hkd->bsd", ctx.astype(dtype),
                   L.get_kernel(params["wo"], dtype))
    return shard(y, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, window, causal: bool):
    """Additive mask bias [..., Sq, Sk].  window: traced int32, <0 = global."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    win_ok = (window < 0) | (d < window)
    ok &= win_ok
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core attention impls (q: [B,Sq,Hp,hd], k/v: [B,Sk,KVp,hd])
# ---------------------------------------------------------------------------


def _gqa_scores(q, k, group: int):
    """-> [B, KVp, G, Sq, Sk] in f32."""
    b, sq, hp, hd = q.shape
    qg = q.reshape(b, sq, hp // group, group, hd)
    return jnp.einsum("bsngk,btnk->bngst", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) / math.sqrt(hd)


def _gqa_out(probs, v, hp: int):
    b, n, g, sq, sk = probs.shape
    ctx = jnp.einsum("bngst,btnk->bsngk", probs, v.astype(jnp.float32))
    return ctx.reshape(b, sq, hp, v.shape[-1])


def attend_reference(q, k, v, q_pos, k_pos, layout: HeadLayout, *,
                     causal: bool, window, cap: float = 0.0,
                     kv_len=None) -> jax.Array:
    scores = _gqa_scores(q, k, layout.group)
    scores = L.softcap(scores, cap)
    bias = _mask_bias(q_pos, k_pos, window, causal)
    if kv_len is not None:                       # decode: mask empty cache slots
        bias = bias + jnp.where(k_pos < kv_len, 0.0, -1e30)[..., None, :]
    scores = scores + bias[:, None, None] if bias.ndim == 3 else scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, layout.h_pad).astype(q.dtype)


def attend_chunked(q, k, v, q_pos, k_pos, layout: HeadLayout, *,
                   causal: bool, window, cap: float = 0.0,
                   q_chunk: int = 1024, kv_chunk: int = 1024,
                   causal_skip: bool = False) -> jax.Array:
    """Streaming-softmax (flash-style) attention in pure lax.  Exact.

    Scans q in blocks; for each q block scans kv blocks carrying running
    (max, denom, acc).  ``causal_skip`` unrolls the q loop and truncates each
    inner scan at the causal frontier (§Perf lever: removes the ~2× masked
    FLOPs of the dense schedule).
    """
    b, sq, hp, hd = q.shape
    sk = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    nq, nk = -(-sq // qc), -(-sk // kc)
    pad_q, pad_k = nq * qc - sq, nk * kc - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=2 ** 30)

    g = layout.group
    n = hp // g
    kb = k.reshape(b, nk, kc, n, hd)
    vb = v.reshape(b, nk, kc, n, hd)
    kpb = k_pos.reshape(b, nk, kc)

    def q_block(qi, kv_hi):
        qs = q[:, qi * qc:(qi + 1) * qc]
        qp = q_pos[:, qi * qc:(qi + 1) * qc]
        qg = qs.reshape(b, qc, n, g, hd).astype(jnp.float32)

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, vj, kpj = xs                        # [b,kc,n,hd],[b,kc]
            s = jnp.einsum("bsngk,btnk->bngst", qg,
                           kj.astype(jnp.float32)) / math.sqrt(hd)
            s = L.softcap(s, cap)
            s = s + _mask_bias(qp, kpj, window, causal)[:, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l = l * scale + jnp.sum(p, axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bngst,btnk->bngsk", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, n, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, n, g, qc), jnp.float32)
        a0 = jnp.zeros((b, n, g, qc, hd), jnp.float32)
        xs = (jnp.moveaxis(kb, 1, 0)[:kv_hi], jnp.moveaxis(vb, 1, 0)[:kv_hi],
              jnp.moveaxis(kpb, 1, 0)[:kv_hi])
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l[..., None])                  # [b,n,g,qc,hd]
        return jnp.moveaxis(out, 3, 1).reshape(b, qc, hp, hd)

    if causal_skip and causal:
        # unrolled q loop; inner scan only over kv blocks at/below the diagonal
        outs = [q_block(i, min(nk, (((i + 1) * qc - 1) // kc) + 1))
                for i in range(nq)]
    else:
        outs = [q_block(i, nk) for i in range(nq)]
    out = jnp.concatenate(outs, axis=1)[:, :sq]
    return out.astype(q.dtype)


def attend_decode(q, k_cache, v_cache, cache_len, layout: HeadLayout, *,
                  window, cap: float = 0.0) -> jax.Array:
    """Single-token decode over a (possibly seq-sharded) KV cache.

    q: [B,1,Hp,hd]; caches: [B,S,KVp,hd].  A plain masked softmax over the
    cache: under a seq-sharded cache GSPMD partitions the reductions into
    flash-decode-style partials + tiny all-reduces (LSE combine).
    """
    b, s, kvp, hd = k_cache.shape
    k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cur = (cache_len[:, None] if cache_len.ndim == 1 else cache_len) - 1
    scores = _gqa_scores(q, k_cache, layout.group)       # [B,KVp,G,1,S]
    scores = L.softcap(scores, cap)
    d = cur[..., :, None] - k_pos[..., None, :]          # [B,1,S]; cur = query pos
    ok = (d >= 0) & ((window < 0) | (d < window))        # d>=0 excludes empty slots
    bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
    scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v_cache, layout.h_pad).astype(q.dtype)


def attend(impl: str, q, k, v, q_pos, k_pos, layout, *, causal, window,
           cap=0.0, q_chunk=1024, kv_chunk=1024, causal_skip=False):
    if impl == "reference":
        return attend_reference(q, k, v, q_pos, k_pos, layout,
                                causal=causal, window=window, cap=cap)
    if impl == "chunked":
        return attend_chunked(q, k, v, q_pos, k_pos, layout, causal=causal,
                              window=window, cap=cap, q_chunk=q_chunk,
                              kv_chunk=kv_chunk, causal_skip=causal_skip)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos,
                                    group=layout.group, causal=causal,
                                    window=window, cap=cap)
    raise ValueError(f"unknown attention impl {impl!r}")
