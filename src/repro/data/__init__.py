from repro.data.synthetic import (  # noqa: F401
    DEFAULT_DOMAINS, Domain, PhaseSchedule, SyntheticCorpus, default_schedule,
)
from repro.data.packing import pack_documents, packing_efficiency  # noqa: F401
from repro.data.loader import PrefetchLoader, host_slice  # noqa: F401
