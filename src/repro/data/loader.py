"""Sharded, prefetching host loader.

In a multi-host deployment each process materializes only its slice of the
global batch (``host_slice``) and builds globally-sharded jax.Arrays; in this
single-process container the slice is the whole batch.  A background thread
prefetches ``depth`` steps ahead — the data pipeline never blocks the step.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np


class PrefetchLoader:
    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 *, start_step: int = 0, depth: int = 2,
                 put_fn: Optional[Callable[[Dict], Any]] = None):
        self.batch_fn = batch_fn
        self.put_fn = put_fn or (lambda x: x)
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self._step
        while not self._stop.is_set():
            try:
                item = (s, self.put_fn(self.batch_fn(s)))
            except Exception as e:           # surface errors on get()
                item = (s, e)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def get(self, expected_step: Optional[int] = None) -> Dict[str, Any]:
        step, item = self._q.get()
        if isinstance(item, Exception):
            raise item
        if expected_step is not None and step != expected_step:
            raise RuntimeError(
                f"loader out of sync: got step {step}, wanted {expected_step}"
                " (reset() after seeking)")
        return item

    def reset(self, step: int):
        self.stop()
        self._q = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._step = step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def host_slice(global_batch: int, n_hosts: int, host_id: int) -> slice:
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)
