"""Greedy sequence packing: variable-length documents -> fixed [B,S] rows
with segment ids and intra-segment positions (FFD bin packing).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def pack_documents(docs: Sequence[np.ndarray], seq_len: int,
                   pad_id: int = 0) -> Dict[str, np.ndarray]:
    """First-fit-decreasing packing.  Returns tokens/segment_ids/positions
    of shape [n_rows, seq_len]; segment id 0 marks padding."""
    order = sorted(range(len(docs)), key=lambda i: -len(docs[i]))
    rows: List[List[np.ndarray]] = []
    space: List[int] = []
    for i in order:
        d = np.asarray(docs[i], np.int32)[:seq_len]
        placed = False
        for r in range(len(rows)):
            if space[r] >= len(d):
                rows[r].append(d)
                space[r] -= len(d)
                placed = True
                break
        if not placed:
            rows.append([d])
            space.append(seq_len - len(d))
    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    seg = np.zeros((n, seq_len), np.int32)
    pos = np.zeros((n, seq_len), np.int32)
    for r, ds in enumerate(rows):
        off = 0
        for j, d in enumerate(ds):
            tokens[r, off:off + len(d)] = d
            seg[r, off:off + len(d)] = j + 1
            pos[r, off:off + len(d)] = np.arange(len(d))
            off += len(d)
    return {"tokens": tokens, "segment_ids": seg, "positions": pos}


def packing_efficiency(packed: Dict[str, np.ndarray]) -> float:
    seg = packed["segment_ids"]
    return float((seg > 0).mean())
