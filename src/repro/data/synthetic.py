"""Deterministic phased synthetic corpus.

Real sampling targets (SPEC ref inputs, LSMS Fe) derive their phase structure
from input data; our corpus induces phases the same way: the token stream
switches between *domains* (disjoint vocab bands + Zipf exponents + length
mixes) on a schedule.  Domain changes shift MoE routing and loss statistics,
so interval BBVs show real phase structure for the selectors to find.

Generation is *stateless*: ``batch_at(step)`` is a pure function of
(seed, step), which makes checkpoint-resume and nugget replay exactly
reproducible — the data cursor is just the step index.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Domain:
    name: str
    vocab_lo: float        # fraction of vocab where this domain's band starts
    vocab_hi: float
    zipf_a: float          # Zipf exponent (higher = more skewed)
    mean_len: int          # mean document length (for packing stats)


DEFAULT_DOMAINS = (
    Domain("web", 0.00, 0.50, 1.2, 512),
    Domain("code", 0.45, 0.80, 1.05, 1024),
    Domain("math", 0.75, 1.00, 1.4, 256),
    Domain("dialog", 0.10, 0.35, 1.3, 128),
)


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    """Which domain mix is active at a given step (piecewise-constant with
    optional cycling — gives the run SimPoint-style recurring phases)."""
    spans: Tuple[Tuple[int, Tuple[float, ...]], ...]  # (length, domain mix)
    cycle: bool = True

    def mix_at(self, step: int) -> Tuple[float, ...]:
        total = sum(s for s, _ in self.spans)
        s = step % total if self.cycle else min(step, total - 1)
        acc = 0
        for length, mix in self.spans:
            acc += length
            if s < acc:
                return mix
        return self.spans[-1][1]


def default_schedule(n_domains: int = 4) -> PhaseSchedule:
    e = np.eye(n_domains)
    mixes = []
    for i in range(n_domains):
        m = 0.7 * e[i] + 0.3 / n_domains
        mixes.append(tuple(m / m.sum()))
    blend = tuple(np.full(n_domains, 1.0 / n_domains))
    spans = tuple([(24, mixes[i]) for i in range(n_domains)] + [(16, blend)])
    return PhaseSchedule(spans)


class SyntheticCorpus:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, domains=DEFAULT_DOMAINS,
                 schedule: Optional[PhaseSchedule] = None,
                 n_frames: int = 0, d_model: int = 0, n_patches: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.domains = domains
        self.schedule = schedule or default_schedule(len(domains))
        self.n_frames, self.d_model, self.n_patches = n_frames, d_model, n_patches

    # ------------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _domain_tokens(self, rng, d: Domain, n: int) -> np.ndarray:
        lo = int(d.vocab_lo * self.vocab_size)
        hi = max(lo + 2, int(d.vocab_hi * self.vocab_size))
        band = hi - lo
        # bounded-Zipf via inverse-CDF on ranks
        ranks = np.arange(1, band + 1, dtype=np.float64)
        w = ranks ** (-d.zipf_a)
        w /= w.sum()
        return lo + rng.choice(band, size=n, p=w)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        mix = np.asarray(self.schedule.mix_at(step))
        b, s = self.global_batch, self.seq_len
        dom_per_row = rng.choice(len(self.domains), size=b, p=mix / mix.sum())
        toks = np.empty((b, s + 1), np.int32)
        for i, di in enumerate(dom_per_row):
            toks[i] = self._domain_tokens(rng, self.domains[di], s + 1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
               "domains": dom_per_row.astype(np.int32)}
        if self.n_frames:
            out["frames"] = rng.standard_normal(
                (b, self.n_frames, self.d_model)).astype(np.float32)
        if self.n_patches:
            out["patches"] = rng.standard_normal(
                (b, self.n_patches, self.d_model)).astype(np.float32)
        return out

    def token_stats(self, step: int) -> Dict[str, float]:
        """Cheap per-step signature extras for the Nugget profile."""
        mix = np.asarray(self.schedule.mix_at(step))
        return {f"domain_mix_{i}": float(m) for i, m in enumerate(mix)}
