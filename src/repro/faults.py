"""Shared failure vocabulary for the whole framework.

One module defines what a *fault* is, so the pipeline scheduler
(``repro.pipeline.scheduler``), the artifact store
(``repro.pipeline.store``) and the distributed heartbeat/restart state
machine (``repro.distributed.faults``) speak the same language:

- **Exceptions** — :class:`TransientError` subclasses retry;
  everything else is fatal and propagates.  :func:`classify` is the
  single transient-vs-fatal decision point.
- **Events** — :func:`fault_event` builds the uniform event record the
  heartbeat coordinator, the fault injector and the scheduler all
  append to their logs (``{"kind": ..., **fields}``).
- **RetryPolicy** — max attempts, exponential backoff with
  *deterministic* jitter (hash of stage name + attempt, never
  ``random``), and an optional per-attempt wall-clock timeout.
- **FaultInjector** — env/CLI-configurable failure injection
  (raise-in-stage, kill-worker-thread, corrupt-payload,
  stall-past-timeout) threaded through the store and scheduler as the
  test/CI backbone.  Decisions are derived from a seed + call counter
  via sha256, so a given spec replays identically.

Spec grammar (``--faults`` / ``REPRO_FAULTS``)::

    spec   := rule (";" rule)*
    rule   := kind [":" param ("," param)*]
    kind   := "raise" | "fatal" | "kill" | "stall" | "corrupt"
    param  := "stage=" fnmatch-pattern    # fire site filter (default *)
            | "p=" float                  # per-call probability
            | "n=" int                    # firing budget (kill/stall/
                                          #   corrupt default to n=1)
            | "s=" float                  # stall seconds (stall only)

Examples::

    raise:stage=profile,p=0.3            # profile attempt fails 30%
    kill:n=1;corrupt:stage=profile,n=1   # one worker death, one
                                         #   corrupted profile payload
    stall:stage=replay@f32,s=600         # hang the f32 replay (the
                                         #   CI crash-resume SIGKILL knob)
"""
from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro import obs

ENV_FAULTS = "REPRO_FAULTS"
ENV_FAULT_SEED = "REPRO_FAULT_SEED"

FAULT_KINDS = ("raise", "fatal", "kill", "stall", "corrupt")


# -- exceptions ---------------------------------------------------------
class FaultError(Exception):
    """Base for framework-originated failures."""


class TransientError(FaultError):
    """Retryable failure: the operation may succeed if attempted again."""


class InjectedFault(TransientError):
    """A ``raise`` rule fired (transient: the retry loop absorbs it)."""


class InjectedFatal(FaultError):
    """A ``fatal`` rule fired (not retried; aborts the run)."""


class StageTimeout(TransientError):
    """A stage attempt exceeded its wall-clock budget."""


class WorkerKilled(TransientError):
    """A worker thread died mid-stage (``kill`` rule, or a real pool
    casualty).  The scheduler reschedules the stage; repeated deaths
    degrade the run to the serial loop."""


def classify(exc: BaseException) -> str:
    """``"transient"`` (retry) or ``"fatal"`` (propagate).

    Transient: the explicit :class:`TransientError` family plus the
    OS-level errors a shared/remote store can throw under contention
    (``OSError`` covers ``ConnectionError``/``BrokenPipeError``) and
    ``TimeoutError``.  Everything else — assertion failures, value
    errors, injected fatals — is a genuine bug and must surface.
    """
    if isinstance(exc, (TransientError, OSError, TimeoutError)):
        return "transient"
    return "fatal"


# -- events -------------------------------------------------------------
def fault_event(kind: str, **fields: Any) -> Dict[str, Any]:
    """Uniform failure-event record shared by the heartbeat coordinator,
    the fault injector and the scheduler logs."""
    return {"kind": kind, **fields}


# -- retry policy -------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Stage retry semantics driven by the DAG scheduler.

    Attempt ``k`` (1-based) that fails with a transient error sleeps
    ``backoff_s * backoff_factor**(k-1)`` scaled by a deterministic
    jitter in ``[1, 1+jitter_frac)`` derived from the stage name and
    attempt number — no global RNG, so reruns back off identically.
    ``timeout_s`` bounds each attempt's wall clock (None = unbounded).
    """

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    jitter_frac: float = 0.25
    max_backoff_s: float = 30.0
    timeout_s: Optional[float] = None

    def delay(self, key: str, attempt: int) -> float:
        base = min(self.backoff_s * self.backoff_factor ** (attempt - 1),
                   self.max_backoff_s)
        h = hashlib.sha256(f"{key}\x00{attempt}".encode()).digest()
        frac = int.from_bytes(h[:8], "big") / 2.0 ** 64
        return base * (1.0 + self.jitter_frac * frac)


# -- injector -----------------------------------------------------------
@dataclasses.dataclass
class FaultRule:
    """One parsed spec rule plus its firing accounting."""

    kind: str
    stage: str = "*"            # fnmatch pattern over the fire site
    p: float = 1.0              # per-call probability
    n: int = -1                 # firing budget (-1 = unlimited)
    s: float = 0.0              # stall seconds
    fired: int = 0
    calls: int = 0


def parse_fault_spec(spec: str) -> List[FaultRule]:
    rules: List[FaultRule] = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        kind, _, params = part.partition(":")
        kind = kind.strip()
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in {spec!r} "
                             f"(expected one of {FAULT_KINDS})")
        kw: Dict[str, Any] = {}
        for item in params.split(",") if params else []:
            k, eq, v = item.partition("=")
            k, v = k.strip(), v.strip()
            if not eq:
                raise ValueError(f"malformed fault param {item!r} in {spec!r}")
            if k == "stage":
                kw["stage"] = v
            elif k == "p":
                kw["p"] = float(v)
            elif k == "n":
                kw["n"] = int(v)
            elif k == "s":
                kw["s"] = float(v)
            else:
                raise ValueError(f"unknown fault param {k!r} in {spec!r}")
        # destructive one-shot kinds default to a budget of one firing
        if kind in ("kill", "stall", "corrupt", "fatal") and "n" not in kw:
            kw["n"] = 1
        rules.append(FaultRule(kind=kind, **kw))
    return rules


class FaultInjector:
    """Deterministic, spec-driven failure injection.

    The scheduler calls :meth:`fire` before every stage attempt; the
    store calls :meth:`corrupt` after every artifact commit.  Each rule
    keeps its own call counter, and probabilistic decisions hash
    ``(seed, rule, site, call#)`` — so a spec + seed replays the exact
    same failure schedule, retries included (each retry is a fresh
    call and gets a fresh draw).
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self.events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultInjector":
        return cls(parse_fault_spec(spec), seed=seed)

    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None
                 ) -> Optional["FaultInjector"]:
        """Build from ``REPRO_FAULTS`` / ``REPRO_FAULT_SEED`` (None when
        unset — the common case costs one dict lookup)."""
        e = os.environ if env is None else env
        spec = e.get(ENV_FAULTS, "").strip()
        if not spec:
            return None
        return cls.from_spec(spec, seed=int(e.get(ENV_FAULT_SEED, "0")))

    # -- decision core -------------------------------------------------
    def _decide(self, idx: int, rule: FaultRule, site: str) -> bool:
        """Under ``self._lock``: consume one call, return whether the
        rule fires (budget + deterministic probability draw)."""
        rule.calls += 1
        if rule.n >= 0 and rule.fired >= rule.n:
            return False
        if rule.p < 1.0:
            h = hashlib.sha256(
                f"{self.seed}\x00{idx}\x00{site}\x00{rule.calls}".encode()
            ).digest()
            if int.from_bytes(h[:8], "big") / 2.0 ** 64 >= rule.p:
                return False
        rule.fired += 1
        return True

    def _record(self, rule: FaultRule, site: str, **extra: Any) -> None:
        ev = fault_event(rule.kind, site=site, call=rule.calls, **extra)
        self.events.append(ev)
        obs.metrics().count(f"faults.{rule.kind}")
        obs.log.kv("fault_injected", logger="faults", kind=rule.kind,
                   site=site, **extra)
        if obs.enabled():
            obs.event("fault.injected", kind=rule.kind, site=site, **extra)

    # -- hook points ---------------------------------------------------
    def fire(self, point: str, site: str) -> None:
        """Scheduler hook, called before each stage attempt.  May sleep
        (``stall``), raise :class:`InjectedFault` / :class:`InjectedFatal`
        (``raise`` / ``fatal``) or :class:`WorkerKilled` (``kill``)."""
        del point  # one fire point today; kept for future store/net hooks
        for idx, rule in enumerate(self.rules):
            if rule.kind == "corrupt":
                continue
            if not fnmatch.fnmatchcase(site, rule.stage):
                continue
            with self._lock:
                fired = self._decide(idx, rule, site)
                if fired:
                    self._record(rule, site)
            if not fired:
                continue
            if rule.kind == "stall":
                time.sleep(rule.s)
            elif rule.kind == "raise":
                raise InjectedFault(f"injected transient failure at {site}")
            elif rule.kind == "fatal":
                raise InjectedFatal(f"injected fatal failure at {site}")
            elif rule.kind == "kill":
                raise WorkerKilled(f"injected worker death at {site}")

    def corrupt(self, dirpath: str, site: str) -> bool:
        """Store hook, called after an artifact commit: flip one byte of
        the first payload file so integrity verification catches it on
        the next cache-hit load.  Returns True if a corruption landed."""
        for idx, rule in enumerate(self.rules):
            if rule.kind != "corrupt":
                continue
            if not fnmatch.fnmatchcase(site, rule.stage):
                continue
            with self._lock:
                if not self._decide(idx, rule, site):
                    continue
                target = None
                for d, _, files in sorted(os.walk(dirpath)):
                    for fn in sorted(files):
                        if fn != "spec.json" and not fn.endswith(".tmp"):
                            target = os.path.join(d, fn)
                            break
                    if target:
                        break
                if target is None:      # nothing to corrupt: refund budget
                    rule.fired -= 1
                    continue
                with open(target, "r+b") as f:
                    first = f.read(1)
                    f.seek(0)
                    f.write(bytes([first[0] ^ 0xFF]) if first else b"\xff")
                self._record(rule, site,
                             file=os.path.relpath(target, dirpath))
            return True
        return False

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "seed": self.seed,
                "rules": [{"kind": r.kind, "stage": r.stage, "p": r.p,
                           "n": r.n, "fired": r.fired, "calls": r.calls}
                          for r in self.rules],
                "events": list(self.events),
            }
