"""Token samplers for decoding."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits: [B,1,V] -> [B,1] int32."""
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    lf = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    lf = lf / temperature
    if top_k > 0:
        kth = jnp.sort(lf, axis=-1)[:, -top_k][:, None]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    tok = jax.random.categorical(rng, lf, axis=-1)
    return tok[:, None].astype(jnp.int32)
