from repro.serve.engine import Request, ServeEngine, SyntheticRequests  # noqa: F401
from repro.serve.sampler import greedy, sample  # noqa: F401
