"""Serving engine: continuous batching over a fixed-shape decode batch.

Requests prefill into a single-row cache (fixed prefill length, padded) and
are inserted into a free decode slot; every engine iteration decodes the full
batch (inactive slots masked).  The engine is a *profiled program*: prefill
and decode iterations emit different hook streams (merged BlockTable), so
serving intervals genuinely vary in composition — the serving analogue of the
paper's multi-phase workloads.  ``snapshot()``/``restore()`` capture engine
state for replay resets and elastic migration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.blocks_lm import build_block_table
from repro.core.intervals import IntervalBuilder, Profile
from repro.core.registry import BlockTable, merge_tables
from repro.models.model_zoo import Model, build_model
from repro.serve.sampler import greedy, sample


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [P] int32
    max_new_tokens: int
    submitted_at: float = 0.0
    output: Optional[List[int]] = None
    finished_at: float = 0.0


class SyntheticRequests:
    """Deterministic request stream (stateless in arrival index)."""

    def __init__(self, vocab: int, *, prompt_len: int = 32,
                 mean_new: int = 24, seed: int = 0):
        self.vocab, self.prompt_len, self.mean_new, self.seed = \
            vocab, prompt_len, mean_new, seed

    def request(self, i: int) -> Request:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, i]))
        p = rng.integers(0, self.vocab, size=self.prompt_len).astype(np.int32)
        n = int(rng.integers(self.mean_new // 2, self.mean_new * 2))
        return Request(i, p, n)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, *, batch: int = 4, max_seq: int = 128,
                 prefill_len: int = 32, seed: int = 0,
                 temperature: float = 0.0, instrument: bool = True,
                 interval_steps: float = 4.0,
                 defer_analysis: bool = True):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.batch, self.max_seq, self.prefill_len = batch, max_seq, prefill_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

        self.table: Optional[BlockTable] = None
        self.builder: Optional[IntervalBuilder] = None
        if instrument:
            # FLOP-weighted unit of work: serving steps are heterogeneous in
            # tensor volume (prefill vs decode), see build_block_table docs
            tp = build_block_table(
                self.model, ShapeConfig("p", "prefill", prefill_len, 1),
                train=False, unit="flops")
            td = build_block_table(
                self.model, ShapeConfig("d", "decode", max_seq, batch),
                train=False, unit="flops")
            self.table = merge_tables({"prefill": tp, "decode": td})
            iu = interval_steps * self.table.step_uow("decode")
            # defer_analysis=True (the default) only logs (kind, dyn) per
            # step and runs the vectorized batch analysis once at
            # profile(); False = legacy per-step replay
            self.builder = IntervalBuilder(self.table, iu,
                                           defer=defer_analysis)

        self.reset()

    # ------------------------------------------------------------------
    def reset(self):
        self.cache = self.model.init_cache(self.batch, self.max_seq)
        self.active = np.zeros(self.batch, bool)
        self.remaining = np.zeros(self.batch, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * self.batch
        self.last_token = jnp.zeros((self.batch, 1), jnp.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.iterations = 0
        self.kinds_log: List[str] = []

    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _insert(self, slot: int, req: Request):
        p = np.zeros(self.prefill_len, np.int32)
        n = min(len(req.prompt), self.prefill_len)
        p[:n] = req.prompt[:n]
        batch = {"tokens": jnp.asarray(p)[None]}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.n_frames,
                                         self.cfg.d_model), jnp.float32)
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros((1, self.cfg.n_patches,
                                          self.cfg.d_model), jnp.float32)
        pre_cache = self.model.init_cache(1, self.max_seq)
        logits, pre_cache, _ = self._prefill(self.model_params, batch,
                                             pre_cache)
        # copy row 0 of the single-row cache into the decode slot
        def put(dst, src, key):
            if key == "length":
                return dst.at[slot].set(src[0])
            return dst.at[:, slot].set(src[:, 0])
        self.cache = {k: put(self.cache[k], pre_cache[k], k)
                      for k in self.cache}
        tok = greedy(logits)
        self.last_token = self.last_token.at[slot].set(tok[0])
        self.active[slot] = True
        self.remaining[slot] = req.max_new_tokens
        req.output = [int(tok[0, 0])]
        self.slot_req[slot] = req
        if self.builder is not None:
            self.builder.add_step(kind="prefill")
        self.kinds_log.append("prefill")
        self.iterations += 1
        obs.metrics().count("serve.prefill_iters")

    def _decode_all(self):
        self.rng, sub = jax.random.split(self.rng)
        logits, self.cache, _ = self._decode(self.model_params,
                                             self.last_token, self.cache)
        if self.temperature > 0:
            tok = sample(logits, sub, temperature=self.temperature)
        else:
            tok = greedy(logits)
        self.last_token = tok
        toks = np.asarray(tok)[:, 0]
        for b in range(self.batch):
            if not self.active[b]:
                continue
            req = self.slot_req[b]
            req.output.append(int(toks[b]))
            self.remaining[b] -= 1
            if (self.remaining[b] <= 0
                    or int(self.cache["length"][b]) >= self.max_seq - 1):
                req.finished_at = time.perf_counter()
                self.done.append(req)
                self.active[b] = False
                self.slot_req[b] = None
        if self.builder is not None:
            self.builder.add_step(kind="decode")
        self.kinds_log.append("decode")
        self.iterations += 1
        obs.metrics().count("serve.decode_iters")

    # ------------------------------------------------------------------
    def step(self, params) -> bool:
        """One engine iteration.  Returns False when idle."""
        self.model_params = params
        free = [b for b in range(self.batch) if not self.active[b]]
        if free and self.queue:
            self._insert(free[0], self.queue.pop(0))
            return True
        if self.active.any():
            self._decode_all()
            return True
        return False

    def run(self, params, requests: List[Request]) -> Dict[str, float]:
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        with obs.span("serve.run", requests=len(requests)):
            while self.step(params):
                pass
            jax.block_until_ready(self.last_token)
        wall = time.perf_counter() - t0
        toks = sum(len(r.output or []) for r in self.done)
        lat = [r.finished_at - r.submitted_at for r in self.done
               if r.finished_at]
        m = obs.metrics()
        m.count("serve.requests", len(self.done))
        m.count("serve.tokens", toks)
        m.record("serve.tokens_per_s", toks / max(wall, 1e-9))
        for v in lat:
            m.observe("serve.latency_s", v)
        return {
            "wall_s": wall,
            "tokens": toks,
            "tokens_per_s": toks / max(wall, 1e-9),
            "requests": len(self.done),
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "iterations": self.iterations,
        }

    # ------------------------------------------------------------------
    def profile(self) -> Profile:
        assert self.builder is not None
        with obs.span("serve.profile_finalize"):
            return self.builder.finalize()

    def snapshot(self) -> Dict[str, Any]:
        """Host-memory engine state (elastic migration / replay resets)."""
        return {
            "cache": jax.tree.map(np.asarray, self.cache),
            "active": self.active.copy(),
            "remaining": self.remaining.copy(),
            "last_token": np.asarray(self.last_token),
            "iterations": self.iterations,
        }

    def restore(self, snap: Dict[str, Any]):
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.active = snap["active"].copy()
        self.remaining = snap["remaining"].copy()
        self.last_token = jnp.asarray(snap["last_token"])
        self.iterations = snap["iterations"]
