"""Architecture registry: ``get_config("qwen3-1.7b")`` etc."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ArchConfig, AttnConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
    shapes_for, reduced, dtype_of,
)

_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma3-4b": "gemma3_4b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-1.7b": "qwen3_1_7b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-1.2b": "zamba2_1_2b",
    "internvl2-76b": "internvl2_76b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
