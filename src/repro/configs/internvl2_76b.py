"""internvl2-76b — VLM: InternViT frontend (STUB patch embeddings) +
InternLM2-76B-style LM backbone.  [arXiv:2404.16821; unverified]
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attn=AttnConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                    rope_theta=1000000.0),
    n_patches=256,
    norm_eps=1e-5,
    source="[arXiv:2404.16821; unverified]",
)
