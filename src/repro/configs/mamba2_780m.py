"""mamba2-780m — attention-free SSD (state-space duality) LM.
[arXiv:2405.21060; unverified]  48L d_model=1536 d_ff=0 vocab=50280 state=128.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, d_conv=4, chunk=256),
    tie_embeddings=True,
    norm_eps=1e-5,
    source="[arXiv:2405.21060; unverified]",
)
