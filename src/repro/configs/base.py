"""Architecture / shape / run configuration dataclasses.

Every assigned architecture gets a module in ``repro.configs`` exporting a
single ``CONFIG: ArchConfig``.  Shapes are the four assignment-wide workload
shapes; each config declares which shapes apply to it (``long_500k`` is only
valid for sub-quadratic-attention families, per DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                     # hidden width of each expert MLP
    n_shared_experts: int = 0         # always-on shared expert(s)
    capacity_factor: float = 1.25     # dense-dispatch capacity bound
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int                      # N in Mamba2 / SSD
    expand: int = 2                   # d_inner = expand * d_model
    head_dim: int = 64                # P; n_heads = d_inner / head_dim
    d_conv: int = 4
    chunk: int = 256                  # SSD chunk length (MXU-aligned)
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False            # qwen2.5-style bias on qkv projections
    # Per-layer sliding window pattern. window <= 0 means global attention.
    # ``local_window``/``global_every`` express gemma3's 5:1 local:global.
    local_window: int = 0             # 0 => all layers global
    global_every: int = 0             # every k-th layer is global (1-indexed)
    softcap: float = 0.0              # logit soft-capping (gemma-style), 0=off


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one SHARED attention block applied every `attn_every`
    # SSM layers (params reused across applications, paper-faithful to the
    # released model family).
    attn_every: int = 0
    # enc-dec (whisper): encoder depth & stubbed frontend frame count.
    n_enc_layers: int = 0
    n_frames: int = 1500              # encoder positions fed by the stub
    # vlm: number of stub patch-embedding positions prepended to the text.
    n_patches: int = 0
    # norm & misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                 # silu | gelu
    glu: bool = True                  # gated MLP (SwiGLU/GeGLU) vs plain
    max_seq_len: int = 1 << 20
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # implementation switches (perf levers; see EXPERIMENTS §Perf)
    attention_impl: str = "chunked"   # reference | chunked | pallas
    ssm_impl: str = "chunked"         # reference | chunked | pallas
    attn_chunk: int = 1024            # KV chunk for streaming attention
    attn_causal_skip: bool = False    # skip above-diagonal kv blocks (§Perf)
    parallel_block: bool = False      # PaLM-style attn∥mlp (1 TP AR/layer)
    remat_group: int = 1              # layers per remat/scan group (§Perf)
    weight_quant: str = "none"        # none | int8 | int4 (weight-only, serving)
    cache_quant: str = "none"         # none | int8 (KV cache, serving)
    remat: str = "full"               # none | full | selective
    scan_layers: bool = True
    source: str = ""                  # provenance note [source; tier]

    # ---- derived ----------------------------------------------------------
    @property
    def is_subquadratic(self) -> bool:
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attn is not None and self.attn.local_window > 0:
            return True                # sliding-window majority (gemma3)
        return False

    @property
    def has_decoder(self) -> bool:
        return True                    # all assigned archs decode (enc-dec incl.)

    def layer_windows(self) -> Tuple[int, ...]:
        """Static per-layer attention window (-1 == global) for the decoder."""
        a = self.attn
        if a is None:
            return tuple()
        out = []
        for i in range(self.n_layers):
            if a.local_window > 0 and a.global_every > 0:
                out.append(-1 if (i + 1) % a.global_every == 0 else a.local_window)
            else:
                out.append(-1)
        return tuple(out)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        total = v * d                                        # embed
        if not self.tie_embeddings:
            total += v * d                                   # lm head
        per_layer = 0
        if self.attn is not None:
            a = self.attn
            qkv = d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
            o = a.n_heads * a.head_dim * d
            per_layer += qkv + o
        if self.family == "moe" and self.moe is not None:
            m = self.moe
            e_mlp = (3 if self.glu else 2) * d * m.d_expert
            per_layer += m.n_experts * e_mlp + d * m.n_experts  # experts+router
            per_layer += m.n_shared_experts * (3 if self.glu else 2) * d * f
        elif self.family in ("ssm",):
            per_layer = _mamba2_params(self)
        elif self.family == "hybrid":
            per_layer = _mamba2_params(self)
        elif f > 0:
            per_layer += (3 if self.glu else 2) * d * f
        per_layer += 2 * d                                   # norms
        total += per_layer * self.n_layers
        if self.family == "hybrid" and self.attn is not None:
            a = self.attn
            total += (d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
                      + a.n_heads * a.head_dim * d + d)      # one shared block
        if self.family == "encdec" and self.attn is not None:
            a = self.attn
            enc_layer = (d * a.n_heads * a.head_dim * 2
                         + 2 * d * a.n_kv_heads * a.head_dim
                         + (3 if self.glu else 2) * d * f + 2 * d)
            cross = (d * a.n_heads * a.head_dim * 2
                     + 2 * d * a.n_kv_heads * a.head_dim + d)
            total += enc_layer * self.n_enc_layers + cross * self.n_layers
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L, m = self.d_model, self.n_layers, self.moe
        e_mlp = (3 if self.glu else 2) * d * m.d_expert
        dense_total = self.param_count() - L * m.n_experts * e_mlp
        return dense_total + L * m.top_k * e_mlp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


def shapes_for(cfg: ArchConfig) -> Sequence[ShapeConfig]:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.is_subquadratic:
        out.append(SHAPES["long_500k"])
    return out


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def _mamba2_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_inner = s.expand * d
    nh = d_inner // s.head_dim
    in_proj = d * (2 * d_inner + 2 * s.d_state + nh)   # z, x, B, C, dt
    conv = (d_inner + 2 * s.d_state) * s.d_conv
    out_proj = d_inner * d
    extra = nh * 2 + d_inner                           # A_log, D, gate norm
    return in_proj + conv + out_proj + extra


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            d_ff: int = 128, vocab: int = 256, seq: int = 32) -> ArchConfig:
    """Smoke-test-sized config of the same family (per assignment)."""
    changes = dict(
        n_layers=n_layers, d_model=d_model, vocab_size=vocab,
        d_ff=min(cfg.d_ff, d_ff) if cfg.d_ff else 0,
        param_dtype="float32", compute_dtype="float32",
        max_seq_len=max(seq * 4, 128),
    )
    if cfg.attn is not None:
        a = cfg.attn
        nh = max(2, min(4, a.n_heads))
        nkv = max(1, min(a.n_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        changes["attn"] = dataclasses.replace(
            a, n_heads=nh, n_kv_heads=nkv, head_dim=16,
            local_window=min(a.local_window, 16) if a.local_window else 0)
    if cfg.moe is not None:
        m = cfg.moe
        changes["moe"] = dataclasses.replace(
            m, n_experts=min(m.n_experts, 4), top_k=min(m.top_k, 2),
            d_expert=32)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.n_enc_layers:
        changes["n_enc_layers"] = 2
        changes["n_frames"] = 16
    if cfg.n_patches:
        changes["n_patches"] = 4
    if cfg.attn_every:
        changes["attn_every"] = 2
    return dataclasses.replace(cfg, **changes)
