"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,                    # shared-expert hidden width
    vocab_size=202048,
    attn=AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                    rope_theta=500000.0),
    moe=MoEConfig(n_experts=16, top_k=1, d_expert=8192, n_shared_experts=1,
                  capacity_factor=1.25),
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
