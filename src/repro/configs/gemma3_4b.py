"""gemma3-4b — dense, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
34L d_model=2560 8H (GQA kv=4) head_dim=256 d_ff=10240 vocab=262144.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab_size=262144,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256, qk_norm=True,
                    local_window=1024, global_every=6, rope_theta=1000000.0),
    tie_embeddings=True,
    act="gelu",
    source="[hf:google/gemma-3-1b-pt; unverified]",
)
