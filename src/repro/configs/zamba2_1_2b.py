"""zamba2-1.2b — hybrid: Mamba2 backbone + one SHARED attention block applied
every 6 layers (params reused).  [arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64.
"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=64),
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, d_conv=4, chunk=256),
    attn_every=6,
    tie_embeddings=True,
    norm_eps=1e-5,
    source="[arXiv:2411.15242; hf]",
)
