"""qwen2.5-14b — dense GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    d_ff=13824,
    vocab_size=152064,
    attn=AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128, qkv_bias=True,
                    rope_theta=1000000.0),
    norm_eps=1e-5,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
)
