"""whisper-tiny — encoder-decoder audio backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356;
unverified]  4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_enc_layers=4,
    n_frames=1500,
    d_model=384,
    d_ff=1536,
    vocab_size=51865,
    attn=AttnConfig(n_heads=6, n_kv_heads=6, head_dim=64),
    tie_embeddings=True,
    act="gelu",
    glu=False,
    norm_eps=1e-5,
    source="[arXiv:2212.04356; unverified]",
)
