"""qwen3-1.7b — dense GQA with qk-norm.  [hf:Qwen/Qwen3-8B; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    d_ff=6144,
    vocab_size=151936,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128, qk_norm=True,
                    rope_theta=1000000.0),
    tie_embeddings=True,
    norm_eps=1e-6,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
