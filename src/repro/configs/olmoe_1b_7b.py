"""olmoe-1b-7b — 64-expert top-8 MoE.  [arXiv:2409.02060; hf]
16L d_model=2048 16H (GQA kv=16) d_expert=1024 vocab=50304.
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    d_ff=1024,
    vocab_size=50304,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                    qk_norm=True, rope_theta=10000.0),
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024, n_shared_experts=0,
                  capacity_factor=1.25),
    norm_eps=1e-5,
    source="[arXiv:2409.02060; hf]",
)
