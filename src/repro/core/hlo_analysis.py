"""Compiled-HLO analysis: op histograms, collective traffic, marker labels.

Three consumers:
- the dry-run (collective bytes for the roofline's third term),
- the model-accuracy case study (paper §V-B: per-nugget compiled-op histogram
  vs portable-IR histogram localizes where the backend "microcodes"
  differently than the IR-level view assumes),
- zero-overhead marker location in "simulation" (named_scope labels survive
  into HLO metadata — the gem5 PC-label analogue).
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]"
    r"(?:\{[^}]*\})?\s+([\w\-]+)\(")
_TUPLE_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\((.*?)\)\s+([\w\-]+)\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    if not dims.strip():
        return b
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * b


def parse_defs(hlo_text: str) -> Dict[str, int]:
    """var name -> result bytes, for every definition line."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, dtype, dims, _op = m.groups()
            sizes[name] = _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_DEF_RE.match(line)
        if m:
            name, inner, _op = m.groups()
            total = 0
            for part in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", inner):
                total += _shape_bytes(part.group(1), part.group(2))
            sizes[name] = total
    return sizes


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Opcode -> count over all computations (incl. fusion bodies)."""
    hist: Dict[str, int] = collections.Counter()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            hist[m.group(4)] += 1
            continue
        m = _TUPLE_DEF_RE.match(line)
        if m:
            hist[m.group(3)] += 1
    return dict(hist)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + operand bytes (roofline 3rd term).

    Operand bytes are resolved through the def-site size map; if an operand
    is unknown (e.g. a parameter), the op's own result size is used as the
    fallback estimate.
    """
    sizes = parse_defs(hlo_text)
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line) or _TUPLE_DEF_RE.match(line)
        if not m:
            continue
        op = m.group(4) if m.re is _DEF_RE else m.group(3)
        base = None
        for kind in COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                base = kind
                break
        if base is None:
            continue
        # operand list: names inside the call parens
        call = line[line.index(op + "(") + len(op) + 1:]
        depth, args = 1, []
        buf = ""
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append(buf)
                buf = ""
            else:
                buf += ch
        if buf.strip():
            args.append(buf)
        total = 0
        for a in args:
            names = re.findall(r"%?([\w.\-]+)", a.strip())
            if names and names[-1] in sizes:
                total += sizes[names[-1]]
        if total == 0:
            name = m.group(1)
            total = sizes.get(name, 0)
        stats[base]["count"] += 1
        stats[base]["bytes"] += float(total)
    return stats


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def find_scope_labels(hlo_text: str, needle: str) -> List[str]:
    """Locate ops whose metadata carries a named_scope label containing
    ``needle`` — zero-overhead marker tracking in the compiled program."""
    out = []
    for line in hlo_text.splitlines():
        if "metadata=" in line and needle in line:
            m = _DEF_RE.match(line) or _TUPLE_DEF_RE.match(line)
            if m:
                out.append(m.group(1))
    return out


def histogram_delta(a: Dict[str, int], b: Dict[str, int]
                    ) -> List[Tuple[str, int, int]]:
    """Sorted (op, count_a, count_b) where counts differ — the §V-B
    'microcoding' localization view."""
    keys = set(a) | set(b)
    rows = [(k, a.get(k, 0), b.get(k, 0)) for k in keys
            if a.get(k, 0) != b.get(k, 0)]
    return sorted(rows, key=lambda r: -abs(r[1] - r[2]))
