"""Unit of work: executed jaxpr primitive operations (DESIGN.md §2).

The paper counts executed LLVM IR instructions; the portable IR of the JAX
ecosystem is the jaxpr.  A block's static "IR size" is the number of jaxpr
equations its traced body contains (recursing into scan/cond/pjit/remat with
static trip counts), exactly as an LLVM IRBB's size is its instruction count.
A FLOP-weighted variant is provided as a secondary unit of work — the paper
notes the unit of work is a pluggable choice that shapes interval semantics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

# primitives that carry sub-jaxprs and their trip-count semantics
_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                  "branches", "fun_jaxpr")


def _sub_jaxprs(eqn) -> Tuple[list, int]:
    """Returns ([(jaxpr, multiplier)], flag_unbounded)."""
    prim = eqn.primitive.name
    out, unbounded = [], 0
    p = eqn.params
    if prim == "scan":
        out.append((p["jaxpr"], int(p["length"])))
    elif prim == "while":
        # unknown trip count: count one iteration, flag it (the paper's
        # data-driven-loop caveat, §IV-A2)
        out.append((p["body_jaxpr"], 1))
        out.append((p["cond_jaxpr"], 1))
        unbounded = 1
    elif prim == "cond":
        # executed ops = one branch; use the mean as the static estimate
        brs = p["branches"]
        for b in brs:
            out.append((b, 1.0 / len(brs)))
    else:
        for k in _SUBJAXPR_KEYS:
            if k in p and p[k] is not None and k != "branches":
                out.append((p[k], 1))
        if prim == "custom_vjp_call" and "fwd_jaxpr_thunk" in p:
            pass
    return out, unbounded


def _as_jaxpr(j):
    return j.jaxpr if isinstance(j, jcore.ClosedJaxpr) else j


_ELTWISE_FREE = {"reshape", "broadcast_in_dim", "squeeze", "transpose",
                 "convert_element_type", "slice", "dynamic_slice",
                 "dynamic_update_slice", "concatenate", "pad", "rev",
                 "gather", "scatter", "scatter-add", "iota", "copy",
                 "stop_gradient"}

# pure annotations: not executed instructions — excluding them keeps the
# unit of work identical across meshes/sharding plans (binary independence)
_ANNOTATION_PRIMS = {"sharding_constraint", "device_put", "mesh_cast",
                     "sharding_cast"}


def eqn_flops(eqn) -> float:
    """Cheap static FLOP estimate for one equation."""
    prim = eqn.primitive.name
    try:
        if prim == "dot_general":
            dnums = eqn.params["dimension_numbers"]
            (lc, rc), (lb, rb) = dnums
            lhs = eqn.invars[0].aval.shape
            out = eqn.outvars[0].aval.shape
            k = math.prod(lhs[i] for i in lc) if lc else 1
            return 2.0 * math.prod(out) * k
        if prim in _ELTWISE_FREE:
            return 0.0
        out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
        if out_avals:
            return float(sum(math.prod(a.shape) for a in out_avals
                             if hasattr(a, "shape")))
    except Exception:
        pass
    return 0.0


def eqn_bytes(eqn) -> float:
    """Operand+result bytes of one equation (no-fusion traffic upper bound)."""
    total = 0.0
    try:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                total += math.prod(aval.shape) * getattr(
                    aval.dtype, "itemsize", 4)
    except Exception:
        pass
    return total


@dataclasses.dataclass
class IRCost:
    ops: float            # executed jaxpr equations (unit of work)
    flops: float          # FLOP-weighted secondary unit
    unbounded_loops: int  # data-dependent while loops encountered
    bytes: float = 0.0    # operand+result bytes (no-fusion upper bound)

    def __add__(self, o: "IRCost") -> "IRCost":
        return IRCost(self.ops + o.ops, self.flops + o.flops,
                      self.unbounded_loops + o.unbounded_loops,
                      self.bytes + o.bytes)

    def scale(self, m: float) -> "IRCost":
        return IRCost(self.ops * m, self.flops * m, self.unbounded_loops,
                      self.bytes * m)


def jaxpr_cost(jaxpr, _memo: Optional[Dict[int, IRCost]] = None) -> IRCost:
    # sub-jaxprs are frequently shared (scan bodies, remat'd branches,
    # repeated pjit calls); memoizing by identity within one top-level call
    # makes the walk linear in *distinct* sub-jaxprs
    jaxpr = _as_jaxpr(jaxpr)
    if _memo is None:
        _memo = {}
    cached = _memo.get(id(jaxpr))
    if cached is not None:
        return cached
    total = IRCost(0.0, 0.0, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _ANNOTATION_PRIMS:
            continue
        subs, unb = _sub_jaxprs(eqn)
        if subs:
            inner = IRCost(0.0, 0.0, unb)
            for sj, mult in subs:
                inner = inner + jaxpr_cost(sj, _memo).scale(mult)
            total = total + inner
            # the control-flow op itself counts as one executed op
            total = total + IRCost(1.0, 0.0, 0)
        else:
            total = total + IRCost(1.0, eqn_flops(eqn), 0, eqn_bytes(eqn))
    _memo[id(jaxpr)] = total
    return total


def trace_cost(fn: Callable, *args, **kwargs) -> IRCost:
    """IR cost of ``fn`` traced at the given (ShapeDtypeStruct or array)
    arguments — the analogue of an LLVM pass measuring an IRBB's size."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(jaxpr)


def struct_like(tree):
    """Map arrays -> ShapeDtypeStructs (cheap tracing of big param trees)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape") else x, tree)
