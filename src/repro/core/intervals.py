"""Interval discovery + signatures (paper §III-C2), host side.

The IntervalBuilder replays each step's hook stream (block ids + per-hook
count-stamps, precomputed from the BlockTable) against the global unit-of-work
counter, closing an interval whenever the counter crosses a multiple of the
interval size — exactly the paper's hook logic.  Each interval gets:

- a **BBV** (block-frequency vector incl. virtual/dynamic entries),
- a **count-stamp vector** (global counter at the last execution of each
  block within the interval),
- the cumulative hit count of every block at its last execution (used to
  derive markers = (block, required-hit-count) pairs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.registry import BlockTable


@dataclasses.dataclass(frozen=True)
class Marker:
    block: int          # block id
    hits: int           # cumulative executions of ``block`` since run start
    uow: float          # counter value at the marked hook (for pro-rating)

    def to_json(self):
        return {"block": int(self.block), "hits": int(self.hits),
                "uow": float(self.uow)}

    @staticmethod
    def from_json(d):
        return Marker(d["block"], d["hits"], d["uow"])


@dataclasses.dataclass
class Interval:
    idx: int
    start_uow: float
    end_uow: float
    end_marker: Marker
    bbv: np.ndarray              # [n_blocks] executions within interval
    stamps: np.ndarray           # [n_blocks] uow at last exec (-1 = never)
    hits_at_stamp: np.ndarray    # [n_blocks] cumulative hits at last exec
    start_step: float            # fractional step position of interval start
    end_step: float


@dataclasses.dataclass
class Profile:
    table: BlockTable
    interval_uow: float
    intervals: List[Interval]
    total_uow: float
    n_steps: int
    step_uow: float
    dyn_history: Dict[str, np.ndarray]   # per-step dynamic values

    @property
    def n_intervals(self) -> int:
        return len(self.intervals)

    def bbv_matrix(self) -> np.ndarray:
        return np.stack([iv.bbv for iv in self.intervals]) \
            if self.intervals else np.zeros((0, self.table.n_blocks))

    def start_marker(self, idx: int) -> Optional[Marker]:
        """Start marker of interval ``idx`` = end marker of ``idx-1``."""
        if idx == 0:
            return None
        return self.intervals[idx - 1].end_marker


class IntervalBuilder:
    def __init__(self, table: BlockTable, interval_uow: float):
        assert interval_uow > 0
        self.table = table
        self.interval_uow = float(interval_uow)
        self.ids, self.cum = table.expand()         # "default" stream
        self.step_total = float(self.cum[-1])       # default-kind step UoW
        self._cur_total = self.step_total
        self.n = table.n_blocks
        self._g = 0.0                               # global counter
        self._cum_hits = np.zeros(self.n, np.int64)
        self._bbv = np.zeros(self.n, np.float64)
        self._stamps = np.full(self.n, -1.0)
        self._hits_at = np.zeros(self.n, np.int64)
        self._ivl_start = 0.0
        self._ivl_start_step = 0.0
        self._step = 0
        self.intervals: List[Interval] = []
        self._dyn: Dict[str, List] = {}
        self._virtual = [(i, b) for i, b in enumerate(table.blocks)
                         if b.virtual]

    # ------------------------------------------------------------------
    def add_step(self, dyn: Optional[Dict[str, Any]] = None,
                 kind: str = "default"):
        if kind == "default":
            ids, cum = self.ids, self.cum
        else:
            ids, cum = self.table.expand(kind)
        self._cur_total = float(cum[-1]) if len(cum) else 0.0
        g0 = self._g
        # record dynamic history
        if dyn:
            for k, v in dyn.items():
                self._dyn.setdefault(k, []).append(np.asarray(v))

        # boundary crossings within this step (counter hits multiples of I)
        I = self.interval_uow
        next_bound = (np.floor(g0 / I) + 1) * I
        abs_cum = g0 + cum
        start = 0
        while next_bound <= abs_cum[-1] + 1e-9:
            j = int(np.searchsorted(abs_cum, next_bound - 1e-9, side="left"))
            j = min(j, len(ids) - 1)
            self._consume(ids, cum, start, j + 1, g0)
            self._close(abs_cum[j], ids[j],
                        step_frac=self._step + (j + 1) / len(ids), dyn=dyn)
            start = j + 1
            # one hook may span several boundaries: the next boundary is the
            # first multiple of I strictly beyond the closing hook (no
            # zero-width intervals — paper hook semantics)
            next_bound = (np.floor(abs_cum[j] / I + 1e-12) + 1) * I
        if start < len(ids):
            self._consume(ids, cum, start, len(ids), g0)
        self._g = abs_cum[-1]
        self._step += 1

    def _consume(self, all_ids, all_cum, lo: int, hi: int, g0: float):
        ids, cum = all_ids[lo:hi], all_cum[lo:hi]
        if len(ids) == 0:
            return
        np.add.at(self._bbv, ids, 1.0)
        np.add.at(self._cum_hits, ids, 1)
        # last-write-wins fancy assignment = last execution per block
        self._stamps[ids] = g0 + cum
        self._hits_at[ids] = self._cum_hits[ids]

    def _close(self, end_uow: float, end_block: int, step_frac: float,
               dyn: Optional[Dict[str, Any]]):
        bbv = self._bbv.copy()
        # virtual signature entries: pro-rate this step's dynamic values by
        # the uow fraction the interval took of the step
        if dyn:
            cur = self._cur_total    # self._g is still the step-start UoW here
            frac = min(1.0, (end_uow - max(self._ivl_start, self._g))
                       / cur) if cur else 0.0
            for i, b in self._virtual:
                if b.dyn_key in dyn:
                    v = np.asarray(dyn[b.dyn_key], np.float64)
                    val = v[b.dyn_index] if (b.dyn_index >= 0 and v.ndim) else v
                    bbv[i] += float(val) * max(frac, 0.0)
        marker = Marker(int(end_block), int(self._cum_hits[end_block]),
                        float(end_uow))
        self.intervals.append(Interval(
            idx=len(self.intervals),
            start_uow=self._ivl_start,
            end_uow=float(end_uow),
            end_marker=marker,
            bbv=bbv,
            stamps=self._stamps.copy(),
            hits_at_stamp=self._hits_at.copy(),
            start_step=self._ivl_start_step,
            end_step=step_frac,
        ))
        self._bbv[:] = 0.0
        self._stamps[:] = -1.0
        self._hits_at[:] = 0
        self._ivl_start = float(end_uow)
        self._ivl_start_step = step_frac

    # ------------------------------------------------------------------
    def finalize(self) -> Profile:
        dyn_hist = {k: np.stack(v) for k, v in self._dyn.items()}
        return Profile(
            table=self.table,
            interval_uow=self.interval_uow,
            intervals=self.intervals,
            total_uow=self._g,
            n_steps=self._step,
            step_uow=self.step_total,
            dyn_history=dyn_hist,
        )


def build_profile_from_steps(table: BlockTable, n_steps: int,
                             interval_uow: float,
                             dyn_per_step: Optional[List[Dict]] = None
                             ) -> Profile:
    b = IntervalBuilder(table, interval_uow)
    for i in range(n_steps):
        b.add_step(dyn_per_step[i] if dyn_per_step else None)
    return b.finalize()
