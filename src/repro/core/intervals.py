"""Interval discovery + signatures (paper §III-C2), host side.

The IntervalBuilder replays each step's hook stream (block ids + per-hook
count-stamps, precomputed from the BlockTable) against the global unit-of-work
counter, closing an interval whenever the counter crosses a multiple of the
interval size — exactly the paper's hook logic.  Each interval gets:

- a **BBV** (block-frequency vector incl. virtual/dynamic entries),
- a **count-stamp vector** (global counter at the last execution of each
  block within the interval),
- the cumulative hit count of every block at its last execution (used to
  derive markers = (block, required-hit-count) pairs).

Three build paths produce bit-for-bit identical Profiles:

- ``add_step``  — legacy per-step replay (reference implementation),
- ``add_steps`` — vectorized batch path (one cumsum/searchsorted/bincount
  pass over the concatenated hook stream; see ``intervals_vec``),
- ``build_profile_parallel`` — chunked ``concurrent.futures`` analysis whose
  per-chunk partial states merge associatively.

``IntervalBuilder(..., defer=True)`` only *logs* steps as they stream in
(near-zero per-step cost inside a training/serving loop) and runs the batch
analysis once at ``finalize()``.  ``step_log`` always records the full
``(kind, dyn)`` stream — it is the content-addressed cache key input for
``profile_store.cached_build`` / ``cached_finalize``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.intervals_vec import (ChunkResult, Step, analyze_steps,
                                      analyze_steps_parallel, as_steps)
from repro.core.registry import BlockTable


@dataclasses.dataclass(frozen=True, slots=True)
class Marker:
    block: int          # block id
    hits: int           # cumulative executions of ``block`` since run start
    uow: float          # counter value at the marked hook (for pro-rating)

    def to_json(self):
        return {"block": int(self.block), "hits": int(self.hits),
                "uow": float(self.uow)}

    @staticmethod
    def from_json(d):
        return Marker(d["block"], d["hits"], d["uow"])


@dataclasses.dataclass(slots=True)
class Interval:
    idx: int
    start_uow: float
    end_uow: float
    end_marker: Marker
    bbv: np.ndarray              # [n_blocks] executions within interval
    stamps: np.ndarray           # [n_blocks] uow at last exec (-1 = never)
    hits_at_stamp: np.ndarray    # [n_blocks] cumulative hits at last exec
    start_step: float            # fractional step position of interval start
    end_step: float


@dataclasses.dataclass
class Profile:
    table: BlockTable
    interval_uow: float
    intervals: List[Interval]
    total_uow: float
    n_steps: int
    step_uow: float
    dyn_history: Dict[str, np.ndarray]   # per-step dynamic values

    @property
    def n_intervals(self) -> int:
        return len(self.intervals)

    def bbv_matrix(self) -> np.ndarray:
        return np.stack([iv.bbv for iv in self.intervals]) \
            if self.intervals else np.zeros((0, self.table.n_blocks))

    def start_marker(self, idx: int) -> Optional[Marker]:
        """Start marker of interval ``idx`` = end marker of ``idx-1``."""
        if idx == 0:
            return None
        return self.intervals[idx - 1].end_marker


class IntervalBuilder:
    def __init__(self, table: BlockTable, interval_uow: float,
                 defer: bool = False):
        assert interval_uow > 0
        self.table = table
        self.interval_uow = float(interval_uow)
        self.ids, self.cum = table.expand()         # "default" stream
        self.step_total = float(self.cum[-1])       # default-kind step UoW
        self._cur_total = self.step_total
        self.n = table.n_blocks
        self._g = 0.0                               # global counter
        self._cum_hits = np.zeros(self.n, np.int64)
        self._bbv = np.zeros(self.n, np.float64)
        self._stamps = np.full(self.n, -1.0)
        self._hits_at = np.zeros(self.n, np.int64)
        self._ivl_start = 0.0
        self._ivl_start_step = 0.0
        self._step = 0
        self.intervals: List[Interval] = []
        self._dyn: Dict[str, List] = {}
        self._virtual = [(i, b) for i, b in enumerate(table.blocks)
                         if b.virtual]
        # per-builder hook-stream memo: one expansion per kind per builder
        self._streams: Dict[str, Tuple[np.ndarray, np.ndarray]] = {
            "default": (self.ids, self.cum)}
        self.step_log: List[Step] = []   # full (kind, dyn) stream, in order
        self._defer = defer              # True: analyze lazily at finalize()
        self._processed = 0              # prefix of step_log already analyzed

    def _stream(self, kind: str) -> Tuple[np.ndarray, np.ndarray]:
        try:
            return self._streams[kind]
        except KeyError:
            return self._streams.setdefault(kind, self.table.expand(kind))

    @property
    def deferred(self) -> bool:
        """True when steps are only logged and analyzed at ``finalize``."""
        return self._defer

    # ------------------------------------------------------------------
    def add_step(self, dyn: Optional[Dict[str, Any]] = None,
                 kind: str = "default"):
        """Legacy per-step replay (the reference implementation)."""
        self.step_log.append((kind, dyn))
        if self._defer:
            return
        self._add_step_eager(dyn, kind)
        self._processed += 1

    def _add_step_eager(self, dyn: Optional[Dict[str, Any]],
                        kind: str) -> None:
        ids, cum = self._stream(kind)
        self._cur_total = float(cum[-1]) if len(cum) else 0.0
        g0 = self._g
        # record dynamic history
        if dyn:
            for k, v in dyn.items():
                self._dyn.setdefault(k, []).append(np.asarray(v))

        # boundary crossings within this step (counter hits multiples of I)
        I = self.interval_uow
        next_bound = (np.floor(g0 / I) + 1) * I
        abs_cum = g0 + cum
        start = 0
        while next_bound <= abs_cum[-1] + 1e-9:
            j = int(np.searchsorted(abs_cum, next_bound - 1e-9, side="left"))
            j = min(j, len(ids) - 1)
            self._consume(ids, cum, start, j + 1, g0)
            self._close(abs_cum[j], ids[j],
                        step_frac=self._step + (j + 1) / len(ids), dyn=dyn)
            start = j + 1
            # one hook may span several boundaries: the next boundary is the
            # first multiple of I strictly beyond the closing hook (no
            # zero-width intervals — paper hook semantics)
            next_bound = (np.floor(abs_cum[j] / I + 1e-12) + 1) * I
        if start < len(ids):
            self._consume(ids, cum, start, len(ids), g0)
        self._g = abs_cum[-1]
        self._step += 1

    def _consume(self, all_ids, all_cum, lo: int, hi: int, g0: float):
        ids, cum = all_ids[lo:hi], all_cum[lo:hi]
        if len(ids) == 0:
            return
        np.add.at(self._bbv, ids, 1.0)
        np.add.at(self._cum_hits, ids, 1)
        # last-write-wins fancy assignment = last execution per block
        self._stamps[ids] = g0 + cum
        self._hits_at[ids] = self._cum_hits[ids]

    def _close(self, end_uow: float, end_block: int, step_frac: float,
               dyn: Optional[Dict[str, Any]]):
        bbv = self._bbv.copy()
        # virtual signature entries: pro-rate this step's dynamic values by
        # the uow fraction the interval took of the step
        if dyn:
            cur = self._cur_total    # self._g is still the step-start UoW here
            frac = min(1.0, (end_uow - max(self._ivl_start, self._g))
                       / cur) if cur else 0.0
            for i, b in self._virtual:
                if b.dyn_key in dyn:
                    v = np.asarray(dyn[b.dyn_key], np.float64)
                    val = v[b.dyn_index] if (b.dyn_index >= 0 and v.ndim) else v
                    bbv[i] += float(val) * max(frac, 0.0)
        marker = Marker(int(end_block), int(self._cum_hits[end_block]),
                        float(end_uow))
        self.intervals.append(Interval(
            idx=len(self.intervals),
            start_uow=self._ivl_start,
            end_uow=float(end_uow),
            end_marker=marker,
            bbv=bbv,
            stamps=self._stamps.copy(),
            hits_at_stamp=self._hits_at.copy(),
            start_step=self._ivl_start_step,
            end_step=step_frac,
        ))
        self._bbv[:] = 0.0
        self._stamps[:] = -1.0
        self._hits_at[:] = 0
        self._ivl_start = float(end_uow)
        self._ivl_start_step = step_frac

    # ------------------------------------------------------------------
    # batch (vectorized) path
    # ------------------------------------------------------------------
    def add_steps(self, steps: Optional[Sequence[Step]] = None, *,
                  n_steps: Optional[int] = None,
                  dyn_per_step: Optional[Sequence[Optional[Dict]]] = None,
                  kinds: Optional[Sequence[str]] = None) -> None:
        """Vectorized batch path: analyze a run of steps in one pass.

        Accepts either an explicit ``[(kind, dyn), ...]`` stream or the
        ``n_steps``/``dyn_per_step``/``kinds`` spelling.  Produces exactly
        the intervals the equivalent sequence of ``add_step`` calls would.
        """
        steps = as_steps(n_steps=n_steps, dyn_per_step=dyn_per_step,
                         kinds=kinds, steps=steps)
        self.step_log.extend(steps)
        if self._defer:
            return
        self._process_batch(steps)
        self._processed += len(steps)

    def _process_batch(self, steps: Sequence[Step]) -> None:
        if not steps:
            return
        res = analyze_steps(self.table, self.interval_uow, steps,
                            g0=self._g, step0=self._step,
                            baseline_hits=self._cum_hits,
                            expand=self._stream)
        self._absorb(res, steps)

    def absorb(self, res: ChunkResult, steps: Sequence[Step]) -> None:
        """Merge an externally-computed chunk (see ``analyze_steps_parallel``)
        into the builder.  Chunks must arrive in stream order."""
        self.step_log.extend(steps)
        self._processed += len(steps)
        self._absorb(res, steps)

    def _absorb(self, res: ChunkResult, steps: Sequence[Step]) -> None:
        # Associative merge of a chunk's partial state: the carried open
        # interval flows into the chunk's first close (counts add; the
        # chunk's stamps/hits win for blocks it touched), the chunk's
        # trailing open state becomes the new carry.  Virtual-block (dyn)
        # contributions are applied after count merging so float addition
        # order matches the legacy path bit-for-bit.
        n_cl = len(res.end_uow)
        dyn_by_row: Dict[int, List[Tuple[int, float]]] = {}
        for r, i, v in res.dyn_add:
            dyn_by_row.setdefault(r, []).append((i, v))
        # plain-python scalars up front: the append loop below runs once per
        # closed interval and dominates batch-path absorb time
        eu = res.end_uow.tolist()
        es = res.end_step.tolist()
        mb = res.marker_block.tolist()
        mh = res.marker_hits.tolist()
        counts, stamps, hits = res.counts, res.stamps, res.hits
        ivls = self.intervals
        prev_eu, prev_es = self._ivl_start, self._ivl_start_step
        for r in range(n_cl):
            if r == 0:
                touched = counts[0] > 0
                bbv = counts[0] + self._bbv
                stp = np.where(touched, stamps[0], self._stamps)
                hit = np.where(touched, hits[0], self._hits_at)
            else:
                bbv, stp, hit = counts[r], stamps[r], hits[r]
            if dyn_by_row:
                for i, v in dyn_by_row.get(r, ()):
                    bbv[i] += v
            ivls.append(Interval(
                idx=len(ivls), start_uow=prev_eu, end_uow=eu[r],
                end_marker=Marker(mb[r], mh[r], eu[r]), bbv=bbv,
                stamps=stp, hits_at_stamp=hit, start_step=prev_es,
                end_step=es[r]))
            prev_eu, prev_es = eu[r], es[r]
        if n_cl:
            self._bbv = res.counts[n_cl].copy()
            self._stamps = res.stamps[n_cl].copy()
            self._hits_at = res.hits[n_cl].copy()
            self._ivl_start = float(res.end_uow[-1])
            self._ivl_start_step = float(res.end_step[-1])
        else:
            tail = res.counts[0]
            touched = tail > 0
            self._bbv = self._bbv + tail
            self._stamps = np.where(touched, res.stamps[0], self._stamps)
            self._hits_at = np.where(touched, res.hits[0], self._hits_at)
        self._g = res.g_end
        self._cum_hits = res.hits_end.copy()
        self._step += res.n_steps
        for _, dyn in steps:
            if dyn:
                for k, v in dyn.items():
                    self._dyn.setdefault(k, []).append(np.asarray(v))

    # ------------------------------------------------------------------
    def finalize_parallel(self, *, chunk_steps: Optional[int] = None,
                          max_workers: Optional[int] = None) -> Profile:
        """Sharded ``finalize``: the pending (deferred) step log is split
        into whole-step chunks, analyzed concurrently on a thread pool and
        merged in stream order — bit-for-bit identical to ``finalize()``.
        The chunk starts are positioned at the builder's current state
        (global counter, step index, cumulative hits), so the path also
        works after eager/absorbed prefixes.
        """
        pending = self.step_log[self._processed:]
        if pending:
            results = analyze_steps_parallel(
                self.table, self.interval_uow, pending,
                chunk_steps=chunk_steps, max_workers=max_workers,
                g0=self._g, step0=self._step, baseline_hits=self._cum_hits)
            self._processed = len(self.step_log)
            for res, chunk in results:
                self._absorb(res, chunk)
        return self.finalize()

    def finalize(self) -> Profile:
        if self._processed < len(self.step_log):   # deferred analysis
            pending = self.step_log[self._processed:]
            self._processed = len(self.step_log)
            self._process_batch(pending)
        dyn_hist = {k: np.stack(v) for k, v in self._dyn.items()}
        return Profile(
            table=self.table,
            interval_uow=self.interval_uow,
            intervals=self.intervals,
            total_uow=self._g,
            n_steps=self._step,
            step_uow=self.step_total,
            dyn_history=dyn_hist,
        )


def build_profile_from_steps(table: BlockTable, n_steps: int,
                             interval_uow: float,
                             dyn_per_step: Optional[List[Dict]] = None,
                             *, kinds: Optional[Sequence[str]] = None,
                             method: str = "batch",
                             chunk_steps: Optional[int] = None,
                             max_workers: Optional[int] = None) -> Profile:
    """Build a Profile from a step stream.

    ``method`` selects the build path — ``"batch"`` (vectorized, default),
    ``"legacy"`` (per-step reference) or ``"parallel"`` (chunked thread
    pool); all three produce bit-for-bit identical Profiles.
    """
    steps = as_steps(n_steps=n_steps, dyn_per_step=dyn_per_step, kinds=kinds)
    return build_profile(table, interval_uow, steps, method=method,
                         chunk_steps=chunk_steps, max_workers=max_workers)


def build_profile(table: BlockTable, interval_uow: float,
                  steps: Sequence[Step], *, method: str = "batch",
                  chunk_steps: Optional[int] = None,
                  max_workers: Optional[int] = None) -> Profile:
    """Like :func:`build_profile_from_steps` but takes an explicit
    ``[(kind, dyn), ...]`` stream (serving-style heterogeneous steps)."""
    b = IntervalBuilder(table, interval_uow)
    if method == "legacy":
        for kind, dyn in steps:
            b.add_step(dyn, kind=kind)
    elif method == "batch":
        b.add_steps(steps)
    elif method == "parallel":
        for res, chunk in analyze_steps_parallel(
                table, interval_uow, steps, chunk_steps=chunk_steps,
                max_workers=max_workers):
            b.absorb(res, chunk)
    else:
        raise ValueError(f"unknown build method {method!r}")
    return b.finalize()


def build_profile_parallel(table: BlockTable, interval_uow: float,
                           steps: Sequence[Step], *,
                           chunk_steps: Optional[int] = None,
                           max_workers: Optional[int] = None) -> Profile:
    """Chunked parallel build (``concurrent.futures`` thread pool)."""
    return build_profile(table, interval_uow, steps, method="parallel",
                         chunk_steps=chunk_steps, max_workers=max_workers)
