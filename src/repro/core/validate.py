"""Sample validation: weighted extrapolation, prediction error, speedup
error, and the cross-platform consistency analysis the paper identifies as
the strongest quality signal (§IV-B2, §V-A).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.intervals import Profile
from repro.core.nugget import Nugget
from repro.core.replay import ReplayResult, StepRunner, measure_full_run


def predict_total_time(profile: Profile, results: Sequence[ReplayResult]
                       ) -> float:
    """Predicted full-run time = n_intervals * sum_i w_i * t_i  (cluster-size
    weights; SimPoint-style extrapolation)."""
    n = profile.n_intervals
    return n * float(sum(r.weight * r.region_time_s for r in results))


def prediction_error(predicted: float, actual: float) -> float:
    return (predicted - actual) / actual


@dataclasses.dataclass
class PlatformResult:
    platform: str
    predicted: float
    actual: float

    @property
    def error(self) -> float:
        return prediction_error(self.predicted, self.actual)


def speedup_error_matrix(platforms: List[PlatformResult]
                         ) -> List[Dict[str, float]]:
    """Paper §V-A: error in *predicted speedup* for every platform pair —
    usually far tighter than absolute-runtime error."""
    out = []
    for a, b in itertools.combinations(platforms, 2):
        true_sp = a.actual / b.actual
        pred_sp = a.predicted / b.predicted
        out.append({
            "pair": f"{a.platform}|{b.platform}",
            "true_speedup": true_sp,
            "pred_speedup": pred_sp,
            "abs_speedup_error": abs(pred_sp - true_sp) / true_sp,
        })
    return out


def consistency_report(platforms: List[PlatformResult]) -> Dict[str, float]:
    """Cross-platform consistency (paper: 'consistent prediction error across
    platforms is a stronger indicator of sample quality than low error on a
    single platform')."""
    errs = np.array([p.error for p in platforms])
    return {
        "mean_abs_error": float(np.mean(np.abs(errs))),
        "error_spread": float(errs.max() - errs.min()) if len(errs) else 0.0,
        "error_std": float(errs.std()),
        "consistent": bool(errs.std() < 0.05),
    }


def per_nugget_matrix(results_by_platform: Dict[str, List[ReplayResult]]
                      ) -> Tuple[np.ndarray, List[str], List[int]]:
    """[n_platforms, n_nuggets] region times — the Fig. 7 distribution data."""
    plats = sorted(results_by_platform)
    ids = [r.nugget_id for r in results_by_platform[plats[0]]]
    mat = np.array([[r.region_time_s for r in results_by_platform[p]]
                    for p in plats])
    return mat, plats, ids


def nugget_variability(results_by_platform: Dict[str, List[ReplayResult]]
                       ) -> List[Dict[str, float]]:
    """Flag nuggets whose relative cost varies most across platforms
    (candidates for 'not representative of the true speedup')."""
    mat, plats, ids = per_nugget_matrix(results_by_platform)
    rel = mat / mat.sum(axis=1, keepdims=True)
    out = []
    for j, nid in enumerate(ids):
        out.append({"nugget_id": int(nid),
                    "rel_cost_spread": float(rel[:, j].max() - rel[:, j].min()),
                    "rel_cost_mean": float(rel[:, j].mean())})
    return sorted(out, key=lambda d: -d["rel_cost_spread"])


def full_run_baseline(runner: StepRunner, n_steps: int,
                      *, start: int = 0) -> Dict[str, float]:
    """Validation-side ground truth for one platform, as a JSON-able record.

    All full-run measurement for validation flows through here (and so
    becomes a cacheable artifact) instead of being re-measured ad hoc per
    example/benchmark."""
    return {"n_steps": int(n_steps),
            "actual_s": float(measure_full_run(runner, n_steps, start=start))}


def platform_results(profile: Profile,
                     results_by_platform: Dict[str, List[ReplayResult]],
                     baselines: Dict[str, Dict[str, float]]
                     ) -> List[PlatformResult]:
    """Assemble per-platform predicted-vs-actual pairs from replay-result
    lists and :func:`full_run_baseline` records (platform order preserved)."""
    return [PlatformResult(p, predict_total_time(profile, results_by_platform[p]),
                           float(baselines[p]["actual_s"]))
            for p in results_by_platform]


def validation_report(profile: Profile,
                      results_by_platform: Dict[str, List[ReplayResult]],
                      baselines: Dict[str, Dict[str, float]]) -> Dict:
    """The full §V-A validation summary as one JSON-able dict: per-platform
    prediction error, pairwise speedup errors, cross-platform consistency,
    and per-nugget variability."""
    plats = platform_results(profile, results_by_platform, baselines)
    have_results = all(results_by_platform.values())
    return {
        "platforms": {p.platform: {"predicted_s": p.predicted,
                                   "actual_s": p.actual,
                                   "error": p.error} for p in plats},
        "speedup_errors": speedup_error_matrix(plats) if len(plats) > 1 else [],
        "consistency": consistency_report(plats),
        "nugget_variability": (nugget_variability(results_by_platform)
                               if have_results else []),
    }


def signature_divergence(profile_a: Profile, profile_b: Profile
                         ) -> Dict[str, float]:
    """Cross-platform signature stability (paper §IV-A2: LSMS fp-precision
    loop-count divergence).  Compares per-interval BBVs of two profiles of
    the same workload collected on different platforms."""
    na, nb = profile_a.n_intervals, profile_b.n_intervals
    n = min(na, nb)
    if n == 0:
        return {"intervals_compared": 0, "max_rel_divergence": 0.0,
                "mean_rel_divergence": 0.0, "interval_count_delta": abs(na - nb)}
    A = profile_a.bbv_matrix()[:n]
    B = profile_b.bbv_matrix()[:n]
    denom = np.maximum(np.abs(A) + np.abs(B), 1.0)
    rel = np.abs(A - B) / denom
    return {
        "intervals_compared": n,
        "max_rel_divergence": float(rel.max()),
        "mean_rel_divergence": float(rel.mean()),
        "interval_count_delta": abs(na - nb),
    }
