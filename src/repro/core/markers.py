"""Marker derivation + low-overhead marker search (paper §III-D1/2).

A marker is (block, required-hit-count): the nugget's hooks fire at the
marker block and trigger when its cumulative execution count reaches the
target — identical semantics to the paper.  The low-overhead search trades
precision for cost: within ``search_distance`` unit-of-work of the interval
end (via the count-stamp vector) pick the least-frequently-executed block
(via the BBV), so the runtime hook fires as rarely as possible (§III-D2:
hook frequency should stay < 10 % single-stream / < 1 % synchronized of
total block executions).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.intervals import Interval, Marker, Profile


def end_marker(profile: Profile, idx: int) -> Marker:
    return profile.intervals[idx].end_marker


def start_marker(profile: Profile, idx: int) -> Optional[Marker]:
    return profile.start_marker(idx)


def low_overhead_marker(profile: Profile, idx: int,
                        search_distance: float) -> Marker:
    """Least-frequent block whose last execution lies within
    ``search_distance`` UoW of the interval end."""
    iv = profile.intervals[idx]
    lo = iv.end_uow - search_distance
    cands = np.nonzero((iv.stamps >= lo) & (iv.stamps >= 0))[0]
    if len(cands) == 0:
        return iv.end_marker
    freqs = iv.bbv[cands]
    best = cands[np.argmin(freqs)]
    return Marker(int(best), int(iv.hits_at_stamp[best]),
                  float(iv.stamps[best]))


def marker_hook_fraction(profile: Profile, marker: Marker,
                         interval_ids: List[int]) -> float:
    """Fraction of all block executions that are marker-hook fires across the
    given intervals (the paper's Fig. 6 normalized hook-execution count)."""
    total = 0.0
    hook = 0.0
    for i in interval_ids:
        iv = profile.intervals[i]
        total += float(iv.bbv.sum())
        hook += float(iv.bbv[marker.block])
    return hook / max(total, 1.0)


def marker_precision_loss(profile: Profile, idx: int, m: Marker) -> float:
    """UoW distance between the chosen marker and the true interval end."""
    return float(profile.intervals[idx].end_uow - m.uow)


@dataclasses.dataclass
class MarkerPlan:
    """Resolved markers for one nugget (paper Fig. 1 'nugget creation')."""
    start: Optional[Marker]          # None = program start
    end: Marker
    warmup_start: Optional[Marker]   # None = no warmup / program start
    hook_fraction: float
    precision_loss_uow: float


def plan_markers(profile: Profile, idx: int, *, warmup_intervals: int = 1,
                 search_distance: float = 0.0) -> MarkerPlan:
    iv = profile.intervals[idx]
    if search_distance > 0:
        end = low_overhead_marker(profile, idx, search_distance)
        loss = marker_precision_loss(profile, idx, end)
    else:
        end = iv.end_marker
        loss = 0.0
    start = profile.start_marker(idx)
    w_idx = idx - warmup_intervals
    warm = (profile.start_marker(w_idx + 1) if w_idx >= 0 else None) \
        if warmup_intervals > 0 else start
    frac = marker_hook_fraction(profile, end, [idx])
    return MarkerPlan(start, end, warm, frac, loss)
