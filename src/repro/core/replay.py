"""Nugget replay engine (paper §III-E + §V-A experimental setup).

A *platform* is anything that can run steps: a StepRunner wraps (step_fn,
state-reset) so the same nuggets validate across dtype/XLA-option/mesh/impl
platforms on this host, and across real TPU hosts in production.  Replay:

1. position at the nugget's checkpoint step (``runner.reset``),
2. fast-forward to the warmup marker (untimed — KVM-fast-forward analogue),
3. run warmup steps (microarchitectural-state warmup analogue: here it warms
   compilation caches, host caches and, for serving, the KV cache),
4. time the marker-bounded region; boundary steps are pro-rated by UoW.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Protocol

import numpy as np

from repro import obs
from repro.core.intervals import Profile
from repro.core.nugget import Nugget


class StepRunner(Protocol):
    def reset(self, step: int) -> Any: ...
    def run_step(self, state: Any, step: int) -> Any: ...
    def sync(self, state: Any) -> None: ...


@dataclasses.dataclass
class SimpleRunner:
    """Wraps a jit'd step closure + reset for replay."""
    reset_fn: Callable[[int], Any]
    step_fn: Callable[[Any, int], Any]
    sync_fn: Optional[Callable[[Any], None]] = None

    def reset(self, step: int) -> Any:
        return self.reset_fn(step)

    def run_step(self, state: Any, step: int) -> Any:
        return self.step_fn(state, step)

    def sync(self, state: Any) -> None:
        if self.sync_fn is not None:
            self.sync_fn(state)
        else:
            import jax
            jax.block_until_ready(jax.tree.leaves(state)[0])


@dataclasses.dataclass
class ReplayResult:
    nugget_id: int
    interval_idx: int
    weight: float
    region_time_s: float        # marker-bounded, UoW-pro-rated
    steps_timed: int
    warmup_steps: int
    uow: float

    def to_json(self):
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict) -> "ReplayResult":
        return ReplayResult(int(d["nugget_id"]), int(d["interval_idx"]),
                            float(d["weight"]), float(d["region_time_s"]),
                            int(d["steps_timed"]), int(d["warmup_steps"]),
                            float(d["uow"]))


class ReplayEngine:
    def __init__(self, runner: StepRunner, profile: Profile):
        self.runner = runner
        self.profile = profile
        self._compiled = False

    def warm_compile(self) -> None:
        """Throwaway step so the first nugget's timed region never includes
        jit compilation (the simulator-warmup analogue for XLA)."""
        if self._compiled:
            return
        state = self.runner.reset(0)
        state = self.runner.run_step(state, 0)
        self.runner.sync(state)
        self._compiled = True

    def replay(self, nugget: Nugget) -> ReplayResult:
        with obs.span("replay.nugget", nugget=nugget.nugget_id,
                      interval=nugget.interval_idx):
            result = self._replay(nugget)
        m = obs.metrics()
        m.count("replay.nuggets")
        m.observe("replay.region_s", result.region_time_s)
        return result

    def _replay(self, nugget: Nugget) -> ReplayResult:
        self.warm_compile()
        first_step = int(math.floor(nugget.start_step))
        last_step = int(math.ceil(nugget.end_step)) - 1
        warm_first = int(math.floor(nugget.warmup_step))

        state = self.runner.reset(nugget.ckpt_step)
        step = nugget.ckpt_step
        # fast-forward (untimed) to warmup start, then warmup (executed,
        # untimed — the microarchitectural-warmup analogue)
        while step < first_step:
            state = self.runner.run_step(state, step)
            step += 1
        self.runner.sync(state)
        # timed region: ONE sync pair around the whole region so async
        # dispatch pipelines exactly as in the full-run ground truth;
        # boundary steps are pro-rated by their UoW overlap.
        n_steps = last_step - first_step + 1
        t0 = time.perf_counter()
        while step <= last_step:
            state = self.runner.run_step(state, step)
            step += 1
        self.runner.sync(state)
        total = time.perf_counter() - t0
        overlap = 0.0
        for i in range(n_steps):
            s = first_step + i
            lo = max(nugget.start_step, s)
            hi = min(nugget.end_step, s + 1)
            overlap += max(0.0, hi - lo)
        region = total * (overlap / max(n_steps, 1))
        return ReplayResult(nugget.nugget_id, nugget.interval_idx,
                            nugget.weight, region, n_steps,
                            first_step - warm_first, nugget.uow)

    def replay_all(self, nuggets: List[Nugget]) -> List[ReplayResult]:
        return [self.replay(n) for n in nuggets]


def measure_full_run(runner: StepRunner, n_steps: int,
                     *, start: int = 0) -> float:
    """Ground truth: wall time of the entire workload (paper §II-C).
    One throwaway step first so jit compilation never pollutes the
    measurement (all platforms are timed post-compile, like the paper's
    post-warmup hardware runs)."""
    state = runner.reset(start)
    state = runner.run_step(state, start)
    runner.sync(state)
    state = runner.reset(start)
    t0 = time.perf_counter()
    for s in range(start, n_steps):
        state = runner.run_step(state, s)
    runner.sync(state)
    return time.perf_counter() - t0
