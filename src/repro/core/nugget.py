"""Nugget artifacts (paper §III-D): a portable, replayable snippet bounded by
markers, plus warmup region and extrapolation weight.

Adaptation note (DESIGN.md §2): an XLA step is atomic, so replay runs whole
steps and attributes marker-bounded wall time by UoW pro-rating of the two
boundary steps; markers are exact in unit-of-work space.  In "simulation"
(the dry-run/profiler) markers are located by HLO scope label with zero
runtime overhead — the analogue of gem5 PC tracking.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.intervals import Marker, Profile
from repro.core.markers import MarkerPlan, plan_markers
from repro.core.select import Selection


@dataclasses.dataclass
class Nugget:
    nugget_id: int
    interval_idx: int
    weight: float
    plan: MarkerPlan
    # step-space coordinates for the replay engine
    warmup_step: float          # fractional step where warmup starts
    start_step: float
    end_step: float
    uow: float                  # unit-of-work of the measured region
    ckpt_step: int              # nearest checkpointed step <= warmup_step

    def to_json(self) -> Dict:
        return {
            "nugget_id": self.nugget_id,
            "interval_idx": self.interval_idx,
            "weight": self.weight,
            "start": self.plan.start.to_json() if self.plan.start else None,
            "end": self.plan.end.to_json(),
            "warmup_start": (self.plan.warmup_start.to_json()
                             if self.plan.warmup_start else None),
            "hook_fraction": self.plan.hook_fraction,
            "precision_loss_uow": self.plan.precision_loss_uow,
            "warmup_step": self.warmup_step,
            "start_step": self.start_step,
            "end_step": self.end_step,
            "uow": self.uow,
            "ckpt_step": self.ckpt_step,
        }

    @staticmethod
    def from_json(d: Dict) -> "Nugget":
        plan = MarkerPlan(
            Marker.from_json(d["start"]) if d["start"] else None,
            Marker.from_json(d["end"]),
            Marker.from_json(d["warmup_start"]) if d["warmup_start"] else None,
            d["hook_fraction"], d["precision_loss_uow"])
        return Nugget(d["nugget_id"], d["interval_idx"], d["weight"], plan,
                      d["warmup_step"], d["start_step"], d["end_step"],
                      d["uow"], d["ckpt_step"])


def create_nuggets(profile: Profile, selection: Selection, *,
                   warmup_intervals: int = 1,
                   search_distance: float = 0.0,
                   ckpt_every: int = 0) -> List[Nugget]:
    """Paper Fig. 1 'Nugget creation': markers + warmup for each selected
    interval; ``ckpt_every`` aligns replay starts to checkpointed steps."""
    out: List[Nugget] = []
    for nid, (idx, w) in enumerate(zip(selection.interval_ids,
                                       selection.weights)):
        iv = profile.intervals[idx]
        plan = plan_markers(profile, idx, warmup_intervals=warmup_intervals,
                            search_distance=search_distance)
        w_idx = max(0, idx - warmup_intervals)
        warm_step = profile.intervals[w_idx].start_step
        ck = 0
        if ckpt_every > 0:
            ck = int(warm_step // ckpt_every) * ckpt_every
        out.append(Nugget(
            nugget_id=nid, interval_idx=idx, weight=float(w), plan=plan,
            warmup_step=warm_step, start_step=iv.start_step,
            end_step=iv.end_step, uow=iv.end_uow - iv.start_uow,
            ckpt_step=ck))
    return out


def save_nuggets(path: str, nuggets: List[Nugget], selection: Selection):
    with open(path, "w") as f:
        json.dump({"selection": selection.to_json(),
                   "nuggets": [n.to_json() for n in nuggets]}, f, indent=1)


def load_nuggets(path: str):
    with open(path) as f:
        d = json.load(f)
    return ([Nugget.from_json(n) for n in d["nuggets"]],
            Selection.from_json(d["selection"]))
