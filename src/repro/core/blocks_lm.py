"""Per-architecture BlockTable construction (the "interval analysis pass").

This is the analogue of the paper's LLVM pass walking the IR: we trace each
model block once (ShapeDtypeStruct inputs, no allocation), record its jaxpr
op count as the block's IR size, and lay out the step's hook-stream program.
Training steps scale block costs by the traced grad/fwd ratio so the unit of
work covers the whole executed step (forward hook positions, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, dtype_of
from repro.core.registry import BlockDef, BlockTable, Segment
from repro.core.unit_of_work import IRCost, struct_like, trace_cost
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.model_zoo import Model, build_model, cross_entropy


def _spec_struct(specs, dtype):
    """ParamSpec tree -> ShapeDtypeStruct tree (zero-cost tracing inputs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))


def _x_struct(b, s, d, dtype):
    return jax.ShapeDtypeStruct((b, s, d), dtype)


def build_block_table(model: Model, shape: ShapeConfig,
                      *, train: bool = True, unit: str = "ops") -> BlockTable:
    """``unit``: "ops" counts executed jaxpr equations (the default,
    LLVM-IR-instruction analogue; exact for homogeneous step streams);
    "flops" weighs each block by its traced FLOPs — the pluggable
    unit-of-work choice (paper §III-A) needed when steps are heterogeneous
    in tensor volume (serving: a 16-token prefill must out-weigh a 1-token
    decode even though both lower to the same number of jaxpr ops)."""
    cfg = model.cfg
    dims = model.dims
    dt = dtype_of(cfg.compute_dtype)
    b = max(shape.global_batch, 1)
    s = shape.seq_len if shape.kind != "decode" else 1
    d = cfg.d_model
    x = _x_struct(b, s, d, dt)
    pos = jax.ShapeDtypeStruct((b, s), jnp.int32)
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)

    layer_sp = (T.layer_specs(cfg, dims) if cfg.family != "encdec" else None)
    lp = _spec_struct(layer_sp, dt) if layer_sp is not None else None

    blocks: List[BlockDef] = []
    prog: List[Segment] = []

    def add(name: str, cost: IRCost, **kw) -> int:
        blocks.append(BlockDef(name, cost.ops, cost.flops, **kw))
        return len(blocks) - 1

    # ---- embed -----------------------------------------------------------
    emb_sp = {"embedding": jax.ShapeDtypeStruct((dims.vocab_pad, d), dt)}
    c_embed = trace_cost(lambda p, t: L.embed_lookup(p, t, dt), emb_sp, toks)
    i_embed = add("embed", c_embed)
    prog.append(Segment((i_embed,), 1))

    # ---- per-layer blocks --------------------------------------------------
    if cfg.family in ("dense", "moe", "vlm"):
        win = jnp.int32(-1)
        c_attn = trace_cost(
            lambda p, xx, pp: T._attn_block(p, cfg, dims, xx, pp, win,
                                            plus_one=False, aux={})[0],
            lp, x, pos)
        i_attn = add("attn", c_attn)
        if cfg.family == "moe":
            from repro.models import moe as M
            c_moe = trace_cost(
                lambda p, xx: M.moe_mlp(p["moe"], cfg, xx)[0], lp, x)
            i_mlp = add("moe", c_moe)
        else:
            c_mlp = trace_cost(
                lambda p, xx: T._mlp_block(p, cfg, xx, plus_one=False,
                                           aux={}), lp, x)
            i_mlp = add("mlp", c_mlp)
        prog.append(Segment((i_attn, i_mlp), cfg.n_layers))

    elif cfg.family == "ssm":
        c_ssm = trace_cost(
            lambda p, xx: T.ssm_layer(p, cfg, xx)[0], lp, x)
        i_ssm = add("mamba", c_ssm)
        prog.append(Segment((i_ssm,), cfg.n_layers))

    elif cfg.family == "hybrid":
        c_ssm = trace_cost(lambda p, xx: T.ssm_layer(p, cfg, xx)[0], lp, x)
        i_ssm = add("mamba", c_ssm)
        sh_sp = _spec_struct(T.shared_attn_specs(cfg, dims), dt)
        c_sh = trace_cost(
            lambda p, xx, pp: T._shared_attn_block(
                {"shared_attn": p}, cfg, dims, xx, pp)[0], sh_sp, x, pos)
        i_sh = add("shared_attn", c_sh)
        ae, n_groups, rem = T._hybrid_groups(cfg)
        for g in range(n_groups):
            prog.append(Segment((i_ssm,), ae))
            prog.append(Segment((i_sh,), 1))
        if rem:
            prog.append(Segment((i_ssm,), rem))

    elif cfg.family == "encdec":
        from repro.models import encdec as ED
        enc_sp = _spec_struct(ED._enc_layer_specs(cfg, dims), dt)
        dec_sp = _spec_struct(ED._dec_layer_specs(cfg, dims), dt)
        xe = _x_struct(b, cfg.n_frames, d, dt)
        pe = jax.ShapeDtypeStruct((b, cfg.n_frames), jnp.int32)

        def enc_body(p, xx, pp):
            h = ED.layernorm(p["attn_norm"], xx)
            y, _ = ED._self_attn(p["attn"], cfg, dims, h, pp, causal=False, dt=dt)
            xx = xx + y
            h = ED.layernorm(p["mlp_norm"], xx)
            return xx + L.mlp(p["mlp"], h, "gelu", dt)
        c_enc = trace_cost(enc_body, enc_sp, xe, pe)
        i_enc = add("enc_layer", c_enc)

        enc_out = xe

        def dec_body(p, xx, pp, eo):
            h = ED.layernorm(p["attn_norm"], xx)
            y, _ = ED._self_attn(p["attn"], cfg, dims, h, pp, causal=True, dt=dt)
            xx = xx + y
            h = ED.layernorm(p["xattn_norm"], xx)
            k, v = ED._cross_kv(p["xattn"], cfg, dims, eo, dt)
            xx = xx + ED._cross_attend(p["xattn"], cfg, dims, h, k, v, dt)
            h = ED.layernorm(p["mlp_norm"], xx)
            return xx + L.mlp(p["mlp"], h, "gelu", dt)
        c_dec = trace_cost(dec_body, dec_sp, x, pos, enc_out)
        i_dec = add("dec_layer", c_dec)
        prog.append(Segment((i_enc,), cfg.n_enc_layers))
        prog.append(Segment((i_dec,), cfg.n_layers))

    # ---- head (final norm + unembed + loss) --------------------------------
    def head_fn(p, xx, lbl):
        h = L.rmsnorm(p["norm"], xx, cfg.norm_eps)
        logits = h.astype(dt) @ p["head"]
        return cross_entropy(logits, lbl, cfg.vocab_size)[0]
    head_sp = {"norm": {"scale": jax.ShapeDtypeStruct((d,), dt)},
               "head": jax.ShapeDtypeStruct((d, dims.vocab_pad), dt)}
    c_head = trace_cost(head_fn, head_sp, x, toks)
    i_head = add("head", c_head)
    prog.append(Segment((i_head,), 1))

    # ---- virtual (signature-only) blocks -----------------------------------
    if cfg.family == "moe":
        for e in range(cfg.moe.n_experts):
            add(f"expert_tok_{e}", IRCost(0, 0, 0), virtual=True,
                dyn_key="expert_tokens", dyn_index=e)
        add("dropped_tokens", IRCost(0, 0, 0), virtual=True,
            dyn_key="dropped_tokens")

    if unit == "flops":
        blocks = [dataclasses.replace(
            bl, cost_ops=max(1.0, bl.cost_flops)) for bl in blocks]
    table = BlockTable(blocks, prog)

    # ---- train-step scaling (fwd+bwd+optimizer coverage) -------------------
    if train and shape.kind == "train":
        scale = _train_scale(model, shape)
        table = BlockTable(
            [dataclasses.replace(bl, cost_ops=bl.cost_ops * scale,
                                 cost_flops=bl.cost_flops * scale)
             for bl in table.blocks], table.program)
    return table


@functools.lru_cache(maxsize=32)
def _train_scale_cached(name: str, seq: int, batch: int) -> float:
    return 3.0


def _train_scale(model: Model, shape: ShapeConfig) -> float:
    """Traced grad/fwd IR-op ratio on a reduced clone (cheap, cached)."""
    try:
        from repro.configs.base import reduced
        cfg_r = reduced(model.cfg)
        m_r = build_model(cfg_r)
        key = jax.random.PRNGKey(0)
        sp = _spec_struct(m_r.specs(), dtype_of(cfg_r.param_dtype))
        toks = jax.ShapeDtypeStruct((2, 16), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        if cfg_r.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (2, cfg_r.n_frames, cfg_r.d_model), jnp.float32)
        if cfg_r.n_patches:
            batch["patches"] = jax.ShapeDtypeStruct(
                (2, cfg_r.n_patches, cfg_r.d_model), jnp.float32)
        fwd = trace_cost(lambda p: m_r.loss(p, batch)[0], sp)
        bwd = trace_cost(
            lambda p: jax.grad(lambda q: m_r.loss(q, batch)[0])(p), sp)
        return max(1.0, bwd.ops / max(fwd.ops, 1.0))
    except Exception:
        return 3.0
