"""Vectorized + parallel interval analysis — the batch path of the profiler.

The legacy :class:`~repro.core.intervals.IntervalBuilder` replays one step's
hook stream at a time (``np.add.at`` per step, three ``n_blocks`` copies per
closed interval).  This module computes the *same* Profile in large
vectorized passes:

1. **Offsets** — per-step unit-of-work totals are accumulated sequentially
   (``np.cumsum`` is a left-to-right running sum, so the per-step global
   counter values are bit-for-bit the floats the legacy path produces).
2. **Stream** — runs of same-kind steps broadcast the memoized per-kind
   ``(ids, cum)`` expansion into one concatenated ``(ids, abs_uow)`` stream.
3. **Closes** — every interval-boundary multiple each step can cross is
   enumerated up front and located with one batched ``searchsorted``; the
   legacy per-step skip chains (next bound = first multiple strictly past
   the closing hook) then reduce to integer jumps, so close detection is
   O(bounds · log N) vector work plus an O(closes) Python walk.  The
   boundary/epsilon formulas mirror the legacy hook logic exactly,
   including hooks that span several boundaries and multiples that close
   twice because ``m * I`` rounds past an exact step end.
4. **Signatures** — per-interval BBVs come from one segment ``bincount``
   over ``interval_idx * n_blocks + block_id``; last-execution stamps come
   from one in-order flat fancy scatter (last write wins, like the legacy
   per-step assignment); hits-at-last-execution is a closed form — the
   last execution of a block in an interval is its latest, so the hit
   count there is baseline + a row-cumsum of the counts matrix.

Chunk algebra (the parallel path): a chunk of whole steps is analyzable
knowing only its starting global counter, starting step index and baseline
per-block hit counts — all cheaply precomputable — because the legacy
builder re-derives the next interval boundary from the step-start counter at
every ``add_step``.  Each chunk therefore returns its closed intervals plus
a trailing *open state*; chunks merge associatively: the carry's open BBV
adds into the first interval of the next chunk, carry stamps/hits fill the
blocks the next chunk did not touch before its first close.  Dynamic
(virtual-block) contributions are kept separate from the execution counts
until after the merge so floating-point addition order matches the legacy
path bit-for-bit.

Equivalence with the per-step path is asserted by tests
(``tests/test_interval_batch.py``) over randomized mixed-kind streams.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import threading as _threading
import time as _time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.registry import BlockTable

# one profiled step: (step kind, optional dynamic aux dict)
Step = Tuple[str, Optional[Dict[str, Any]]]


def as_steps(n_steps: Optional[int] = None,
             dyn_per_step: Optional[Sequence[Optional[Dict]]] = None,
             kinds: Optional[Sequence[str]] = None,
             steps: Optional[Sequence[Step]] = None) -> List[Step]:
    """Normalize the two step-stream spellings into ``[(kind, dyn), ...]``."""
    if steps is not None:
        return [(k, d) for k, d in steps]
    assert n_steps is not None, "need steps or n_steps"
    return [((kinds[i] if kinds is not None else "default"),
             (dyn_per_step[i] if dyn_per_step is not None else None))
            for i in range(n_steps)]


@dataclasses.dataclass
class ChunkResult:
    """Closed intervals of one run of steps, in array form.

    Row ``r`` of ``counts``/``stamps``/``hits`` describes interval ``r``; the
    last row is the trailing open-interval state.  The *start* of interval 0
    is unknown to the chunk (it lives in the carry) and is filled at merge
    time; ``dyn_add`` holds virtual-block contributions separately so they
    are applied after count merging (exact legacy addition order).
    """
    counts: np.ndarray          # [n_closes+1, n_blocks] float64 exec counts
    stamps: np.ndarray          # [n_closes+1, n_blocks] last-exec uow (-1)
    hits: np.ndarray            # [n_closes+1, n_blocks] int64 hits at stamp
    end_uow: np.ndarray         # [n_closes] float64
    end_step: np.ndarray        # [n_closes] float64 fractional step position
    marker_block: np.ndarray    # [n_closes] int64
    marker_hits: np.ndarray     # [n_closes] int64
    dyn_add: List[Tuple[int, int, float]]   # (interval row, block, value)
    g_end: float                # global counter after the chunk
    hits_end: np.ndarray        # [n_blocks] int64 cumulative hits after chunk
    n_steps: int


def _empty_result(n_blocks: int, g0: float,
                  baseline_hits: np.ndarray) -> ChunkResult:
    return ChunkResult(
        counts=np.zeros((1, n_blocks)),
        stamps=np.full((1, n_blocks), -1.0),
        hits=np.zeros((1, n_blocks), np.int64),
        end_uow=np.zeros(0), end_step=np.zeros(0),
        marker_block=np.zeros(0, np.int64), marker_hits=np.zeros(0, np.int64),
        dyn_add=[], g_end=float(g0), hits_end=baseline_hits.copy(), n_steps=0)


def analyze_steps(table: BlockTable, interval_uow: float,
                  steps: Sequence[Step], *, g0: float = 0.0, step0: int = 0,
                  baseline_hits: Optional[np.ndarray] = None,
                  expand: Optional[Callable] = None) -> ChunkResult:
    """Vectorized interval analysis of a run of steps.

    ``g0``/``step0``/``baseline_hits`` position the run inside a larger
    stream (global counter, step index and per-block cumulative hit counts
    at the start of the run).  ``expand`` overrides the per-kind stream
    lookup (the IntervalBuilder passes its per-builder memo).

    Each batch is timed into the ``intervals.*`` metrics (steps analyzed,
    intervals closed, batch seconds, intervals/s) and traced as an
    ``intervals.analyze_batch`` span when tracing is on.
    """
    t_an0 = _time.perf_counter()
    with obs.span("intervals.analyze_batch", steps=len(steps)) as _sp:
        res = _analyze_steps(table, interval_uow, steps, g0=g0, step0=step0,
                             baseline_hits=baseline_hits, expand=expand)
        n_cl = len(res.end_uow)
        _sp.set(closed=n_cl)
    dt = _time.perf_counter() - t_an0
    m = obs.metrics()
    m.count("intervals.analyzed_steps", len(steps))
    m.count("intervals.closed", n_cl)
    m.observe("intervals.analyze_s", dt)
    if n_cl:
        m.record("intervals.per_s", n_cl / max(dt, 1e-9))
    return res


def _analyze_steps(table: BlockTable, interval_uow: float,
                   steps: Sequence[Step], *, g0: float = 0.0, step0: int = 0,
                   baseline_hits: Optional[np.ndarray] = None,
                   expand: Optional[Callable] = None) -> ChunkResult:
    n = table.n_blocks
    if baseline_hits is None:
        baseline_hits = np.zeros(n, np.int64)
    if expand is None:
        expand = table.expand
    if not len(steps):
        return _empty_result(n, g0, baseline_hits)

    I = float(interval_uow)
    kinds = [k for k, _ in steps]
    streams = {k: expand(k) for k in set(kinds)}
    tot_of = {k: (float(c[-1]) if len(c) else 0.0)
              for k, (_, c) in streams.items()}
    len_of = {k: len(i) for k, (i, _) in streams.items()}

    n_steps = len(steps)
    # runs of consecutive same-kind steps (one boundary scan)
    cuts = [0] + [s for s in range(1, n_steps) if kinds[s] != kinds[s - 1]] \
        + [n_steps]
    runs: List[Tuple[int, int, str]] = [
        (cuts[r], cuts[r + 1], kinds[cuts[r]]) for r in range(len(cuts) - 1)]
    tots = np.empty(n_steps + 1)
    tots[0] = g0
    lens = np.empty(n_steps, np.int64)
    for a, b, k in runs:
        tots[a + 1:b + 1] = tot_of[k]
        lens[a:b] = len_of[k]
    # np.cumsum is a left-to-right running sum -> offs[s] is bit-for-bit the
    # legacy global counter at the start of step s
    offs = np.cumsum(tots)

    # ---- concatenated hook stream (runs of same-kind steps broadcast) ----
    hook0 = np.concatenate([[0], np.cumsum(lens)])      # [n_steps+1]
    ids_parts: List[np.ndarray] = []
    abs_parts: List[np.ndarray] = []
    base = baseline_hits.astype(np.int64, copy=True)   # hits after the chunk
    for a, b, k in runs:
        ids_k, cum_k = streams[k]
        if len(ids_k):
            ids_parts.append(np.tile(ids_k, b - a))
            abs_parts.append((offs[a:b, None] + cum_k[None, :]).ravel())
        base += (b - a) * table.step_counts(k)
    if ids_parts:
        ids = np.concatenate(ids_parts)
        absu = np.concatenate(abs_parts)
    else:
        ids = np.zeros(0, np.int64)
        absu = np.zeros(0)
    N = len(ids)

    # ---- boundary crossings (one vectorized searchsorted, all bounds) ----
    # Legacy semantics, restated per step s: process multiples of I from
    # (floor(offs[s]/I)+1)*I while <= offs[s+1]+1e-9, closing at the first
    # hook >= bound-1e-9 (clamped into the step) and skipping to the first
    # multiple strictly beyond the closing hook.  The skip chain resets at
    # every step boundary (first_bound is re-derived from the step-start
    # counter), so a multiple can legitimately close twice when I*m rounds
    # above the exact step end.  We enumerate each step's candidate
    # multiples, locate all of them with a single batched searchsorted,
    # then walk the per-step skip chains — each hop is one integer jump,
    # so the Python loop is O(n_closes + steps-containing-bounds), not
    # O(hooks).  Streams where a hook lands within 1e-9 below a boundary
    # would make the legacy loop spin forever re-closing the same hook;
    # the chain's forced progress closes such a hook once instead.
    g_end = float(offs[-1])
    step_end = offs[1:]
    m_first = np.floor(offs[:-1] / I) + 1.0
    # conservative last multiple (exact mask below fixes +-1ulp division)
    m_last = np.floor((step_end + 1e-9) / I) + 1.0
    n_bnd = np.maximum((m_last - m_first + 1.0).astype(np.int64), 0)
    n_bnd[lens == 0] = 0                 # empty step stream: nothing closes
    close_pos_l: List[int] = []
    if N and n_bnd.any():
        swb = np.flatnonzero(n_bnd)                  # steps with bounds
        cnts = n_bnd[swb]
        run0 = np.cumsum(cnts) - cnts                # candidate offset/step
        s_of = np.repeat(swb, cnts)
        m = m_first[s_of] + (np.arange(len(s_of)) - np.repeat(run0, cnts))
        bounds = m * I
        ok = bounds <= step_end[s_of] + 1e-9         # exact legacy test
        cand = np.searchsorted(absu, bounds - 1e-9, side="left")
        np.clip(cand, hook0[s_of], hook0[s_of + 1] - 1, out=cand)
        m_skip = np.floor(absu[cand] / I + 1e-12)
        cand_l, ok_l = cand.tolist(), ok.tolist()
        skip_l, mf_l = m_skip.tolist(), m_first[swb].tolist()
        for t, (i0, c) in enumerate(zip(run0.tolist(), cnts.tolist())):
            i, end, off0 = i0, i0 + c, i0 - int(mf_l[t])
            last_j = -1
            while i < end and ok_l[i]:
                j = cand_l[i]
                if j != last_j:
                    close_pos_l.append(j)
                    last_j = j
                i = max(off0 + int(skip_l[i]) + 1, i + 1)
    close_pos = np.array(close_pos_l, np.int64)
    n_cl = len(close_pos)
    e_arr = absu[close_pos] if n_cl else np.zeros(0)
    s_arr = np.searchsorted(hook0, close_pos, side="right") - 1
    jl_arr = close_pos - hook0[s_arr]

    # ---- per-interval segment reductions ---------------------------------
    seg_len = np.diff(np.concatenate([[-1], close_pos, [N - 1]]))
    # flattened (interval, block) key of every hook -> one bincount gives
    # the whole BBV matrix (last row = trailing open interval)
    key = np.repeat(np.arange(n_cl + 1, dtype=np.int64) * n, seg_len) + ids
    counts_int = np.bincount(key, minlength=(n_cl + 1) * n) \
        .reshape(n_cl + 1, n)
    counts = counts_int.astype(np.float64)

    # hits-at-last-execution has a closed form: the last execution of a
    # block inside an interval is by definition its latest one, so the
    # cumulative hit count there == baseline + row-cumsum of the counts
    hits = np.where(counts_int > 0,
                    baseline_hits[None, :] + np.cumsum(counts_int, axis=0),
                    np.int64(0))

    # last-execution stamp per (interval, block): one in-order flat fancy
    # scatter — repeated indices keep the last value written, the same
    # last-write-wins property the legacy _consume() relies on
    stamps = np.full((n_cl + 1) * n, -1.0)
    if N:
        stamps[key] = absu
    stamps = stamps.reshape(n_cl + 1, n)

    # ---- per-close scalars (ends, markers, virtual contributions) --------
    end_uow = e_arr
    end_step = ((step0 + s_arr).astype(np.float64)
                + (jl_arr + 1) / lens[s_arr]) if n_cl else np.zeros(0)
    marker_block = ids[close_pos] if n_cl else np.zeros(0, np.int64)
    marker_hits = hits[np.arange(n_cl), marker_block]

    dyn_add: List[Tuple[int, int, float]] = []
    virtual = [(i, b) for i, b in enumerate(table.blocks) if b.virtual]
    if n_cl and virtual and any(d for _, d in steps):
        prev_e: Optional[float] = None
        prev_s: Optional[int] = None
        for r, (e, s) in enumerate(zip(e_arr.tolist(), s_arr.tolist())):
            dyn = steps[s][1]
            if dyn:
                cur = tot_of[kinds[s]]
                gs = float(offs[s])
                # legacy frac = min(1, (e - max(ivl_start, step_start))/cur):
                # the previous close is only ever > step_start when it
                # happened inside the same step; otherwise (earlier step /
                # earlier chunk / run start) the max resolves to step start.
                start = prev_e if (prev_s == s and prev_e is not None) else gs
                frac = min(1.0, (e - max(start, gs)) / cur) if cur else 0.0
                for i, blk in virtual:
                    if blk.dyn_key in dyn:
                        v = np.asarray(dyn[blk.dyn_key], np.float64)
                        val = v[blk.dyn_index] \
                            if (blk.dyn_index >= 0 and v.ndim) else v
                        dyn_add.append((r, i, float(val) * max(frac, 0.0)))
            prev_e, prev_s = e, s

    hits_end = base          # baseline + per-kind static counts, all integer
    return ChunkResult(counts=counts, stamps=stamps, hits=hits,
                       end_uow=end_uow, end_step=end_step,
                       marker_block=marker_block, marker_hits=marker_hits,
                       dyn_add=dyn_add, g_end=g_end, hits_end=hits_end,
                       n_steps=len(steps))


# ---------------------------------------------------------------------------
# parallel chunked analysis
# ---------------------------------------------------------------------------

def chunk_starts(table: BlockTable, steps: Sequence[Step],
                 bounds: Sequence[Tuple[int, int]], *, g0: float = 0.0,
                 baseline_hits: Optional[np.ndarray] = None
                 ) -> List[Tuple[float, np.ndarray]]:
    """Exact (global counter, baseline hit counts) at each chunk start.

    Both are cheap closed forms: the counter is the running sum of static
    per-step totals (same float op order as the legacy path); the baselines
    are integer sums of the static per-kind execution counts.
    ``g0``/``baseline_hits`` position the whole stream inside a larger run
    (a builder finalizing only its un-analyzed suffix).
    """
    kinds = [k for k, _ in steps]
    tot_of = {k: table.step_uow(k) for k in set(kinds)}
    cnt_of = {k: table.step_counts(k) for k in set(kinds)}
    tots = np.empty(len(steps) + 1)
    tots[0] = float(g0)
    for s, k in enumerate(kinds):
        tots[s + 1] = tot_of[k]
    offs = np.cumsum(tots)
    out: List[Tuple[float, np.ndarray]] = []
    base = (np.zeros(table.n_blocks, np.int64) if baseline_hits is None
            else baseline_hits.astype(np.int64, copy=True))
    done = 0
    for a, b in bounds:
        assert a == done, "chunks must partition the step stream in order"
        out.append((float(offs[a]), base.copy()))
        for s in range(a, b):
            base += cnt_of[kinds[s]]
        done = b
    return out


def analyze_steps_parallel(table: BlockTable, interval_uow: float,
                           steps: Sequence[Step], *,
                           chunk_steps: Optional[int] = None,
                           max_workers: Optional[int] = None,
                           g0: float = 0.0, step0: int = 0,
                           baseline_hits: Optional[np.ndarray] = None
                           ) -> List[Tuple[ChunkResult, Sequence[Step]]]:
    """Fan the step stream out over a thread pool in whole-step chunks.

    Returns the per-chunk results in stream order, ready to be absorbed
    sequentially (the merge is associative; see module docstring).
    ``g0``/``step0``/``baseline_hits`` position the stream inside a larger
    run, so a builder with prior state can shard just its pending suffix.
    """
    n_steps = len(steps)
    workers = max_workers or min(32, (os.cpu_count() or 2))
    if chunk_steps is None:
        chunk_steps = max(1, -(-n_steps // (4 * workers)))
    bounds = [(a, min(a + chunk_steps, n_steps))
              for a in range(0, n_steps, chunk_steps)]
    starts = chunk_starts(table, steps, bounds, g0=g0,
                          baseline_hits=baseline_hits)

    def _chunk(a: int, b: int, g: float, base: np.ndarray) -> ChunkResult:
        obs.set_worker(_threading.current_thread().name)
        return analyze_steps(table, interval_uow, steps[a:b],
                             g0=g, step0=step0 + a, baseline_hits=base)

    table.expand_all()        # warm the per-kind cache before threads race
    with concurrent.futures.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="intervals") as ex:
        futs = [ex.submit(_chunk, a, b, g, base)
                for (a, b), (g, base) in zip(bounds, starts)]
        return [(f.result(), steps[a:b])
                for f, (a, b) in zip(futs, bounds)]
