"""WorkMeter: the in-step hook state (paper §III-C1).

The meter is a small functional pytree threaded through the jit'd step.  Each
step the hooks add the static per-step block counts + dynamic entries to the
block-count vector and bump the two-limb uint32 global unit-of-work counter
(jaxpr default integers are 32-bit; runs exceed 2**32 ops quickly).  Under
data parallelism dynamic counts are psum'd across the "data" axis — the
analogue of the paper's multithreaded hook synchronization whose scaling
Fig. 4 measures (see benchmarks/bench_sync_scaling.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.registry import BlockTable


def init_meter(table: BlockTable) -> Dict[str, jax.Array]:
    return {
        "uow_lo": jnp.zeros((), jnp.uint32),
        "uow_hi": jnp.zeros((), jnp.uint32),
        "counts": jnp.zeros((table.n_blocks,), jnp.int32),
        "steps": jnp.zeros((), jnp.int32),
    }


def _add64(lo: jax.Array, hi: jax.Array, amount: int):
    amt = jnp.uint32(amount & 0xFFFFFFFF)
    hi_amt = jnp.uint32((amount >> 32) & 0xFFFFFFFF)
    new_lo = lo + amt
    carry = (new_lo < amt).astype(jnp.uint32)
    return new_lo, hi + hi_amt + carry


def meter_value(meter) -> int:
    return (int(meter["uow_hi"]) << 32) | int(meter["uow_lo"])


def tick_step(meter: Dict[str, jax.Array], table: BlockTable,
              aux: Optional[Dict[str, jax.Array]] = None,
              kind: str = "default") -> Dict[str, jax.Array]:
    """The per-step hook: O(n_blocks) integer adds inside the jit'd step."""
    static_counts = jnp.asarray(table.step_counts(kind), jnp.int32)
    counts = meter["counts"] + static_counts
    if aux:
        for i, b in enumerate(table.blocks):
            if b.virtual and b.dyn_key and b.dyn_key in aux:
                v = aux[b.dyn_key]
                val = v[b.dyn_index] if (b.dyn_index >= 0 and v.ndim) else v
                counts = counts.at[i].add(val.astype(jnp.int32))
    lo, hi = _add64(meter["uow_lo"], meter["uow_hi"],
                    int(round(table.step_uow(kind))))
    return {"uow_lo": lo, "uow_hi": hi, "counts": counts,
            "steps": meter["steps"] + 1}


def meter_psum(meter: Dict[str, jax.Array], axis_name: str):
    """Cross-shard aggregation (inside shard_map): the sync cost of hooks."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), meter)


def read_meters(meters: Sequence[Dict[str, jax.Array]]
                ) -> List[Dict[str, np.ndarray]]:
    """Batched host readback of device meters: ONE device transfer for the
    whole batch (``jax.device_get`` of the meter pytree list), instead of
    one sync per limb per meter.  Publishes the unit-of-work totals of the
    *last* meter in the batch to the ``meter.*`` gauges (gauges are
    last-write-wins; the final reading is the run total)."""
    if not meters:
        return []
    host = jax.device_get(list(meters))          # single device sync
    out: List[Dict[str, np.ndarray]] = []
    for h in host:
        uow = (int(h["uow_hi"]) << 32) | int(h["uow_lo"])
        steps = int(h["steps"])
        out.append({
            "uow": np.uint64(uow),
            "counts": np.asarray(h["counts"]),
            "steps": steps,
        })
    m = obs.metrics()
    m.count("meter.readbacks")
    last, steps = out[-1], out[-1]["steps"]
    m.record("meter.uow_total", float(last["uow"]))
    m.record("meter.steps", steps)
    if steps:
        m.record("meter.uow_per_step", int(last["uow"]) / steps)
    return out


def read_meter(meter) -> Dict[str, np.ndarray]:
    """Host-side readback of one device meter (one device sync — delegates
    to the batched :func:`read_meters`).  Each readback publishes the
    unit-of-work totals to the ``meter.*`` gauges (one gauge write per
    readback, not per step — readbacks are how UoW leaves the device)."""
    return read_meters([meter])[0]


def materialize_dyn(steps: List, *, chunk: int = 512) -> int:
    """Convert device-resident dynamic aux arrays in a ``(kind, dyn)`` step
    log to host numpy arrays, in place.

    The deferred builder logs the raw per-step aux arrays straight off the
    jit'd step, so the training hot loop never blocks on a device->host
    transfer; this drains them afterwards with **one device sync per
    ``chunk`` of values** (a single ``jax.device_get`` of the whole slice)
    rather than one per interval/step.  Idempotent: host arrays pass
    through untouched.  Returns the number of arrays fetched.
    """
    pend = [(i, k) for i, (_, dyn) in enumerate(steps) if dyn
            for k, v in dyn.items() if isinstance(v, jax.Array)]
    for lo in range(0, len(pend), chunk):
        part = pend[lo:lo + chunk]
        vals = jax.device_get([steps[i][1][k] for i, k in part])  # one sync
        for (i, k), v in zip(part, vals):
            kind, dyn = steps[i]
            dyn = dict(dyn)
            dyn[k] = np.asarray(v)
            steps[i] = (kind, dyn)
    if pend:
        obs.metrics().count("meter.dyn_fetched", len(pend))
    return len(pend)
