"""WorkMeter: the in-step hook state (paper §III-C1).

The meter is a small functional pytree threaded through the jit'd step.  Each
step the hooks add the static per-step block counts + dynamic entries to the
block-count vector and bump the two-limb uint32 global unit-of-work counter
(jaxpr default integers are 32-bit; runs exceed 2**32 ops quickly).  Under
data parallelism dynamic counts are psum'd across the "data" axis — the
analogue of the paper's multithreaded hook synchronization whose scaling
Fig. 4 measures (see benchmarks/bench_sync_scaling.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.registry import BlockTable


def init_meter(table: BlockTable) -> Dict[str, jax.Array]:
    return {
        "uow_lo": jnp.zeros((), jnp.uint32),
        "uow_hi": jnp.zeros((), jnp.uint32),
        "counts": jnp.zeros((table.n_blocks,), jnp.int32),
        "steps": jnp.zeros((), jnp.int32),
    }


def _add64(lo: jax.Array, hi: jax.Array, amount: int):
    amt = jnp.uint32(amount & 0xFFFFFFFF)
    hi_amt = jnp.uint32((amount >> 32) & 0xFFFFFFFF)
    new_lo = lo + amt
    carry = (new_lo < amt).astype(jnp.uint32)
    return new_lo, hi + hi_amt + carry


def meter_value(meter) -> int:
    return (int(meter["uow_hi"]) << 32) | int(meter["uow_lo"])


def tick_step(meter: Dict[str, jax.Array], table: BlockTable,
              aux: Optional[Dict[str, jax.Array]] = None,
              kind: str = "default") -> Dict[str, jax.Array]:
    """The per-step hook: O(n_blocks) integer adds inside the jit'd step."""
    static_counts = jnp.asarray(table.step_counts(kind), jnp.int32)
    counts = meter["counts"] + static_counts
    if aux:
        for i, b in enumerate(table.blocks):
            if b.virtual and b.dyn_key and b.dyn_key in aux:
                v = aux[b.dyn_key]
                val = v[b.dyn_index] if (b.dyn_index >= 0 and v.ndim) else v
                counts = counts.at[i].add(val.astype(jnp.int32))
    lo, hi = _add64(meter["uow_lo"], meter["uow_hi"],
                    int(round(table.step_uow(kind))))
    return {"uow_lo": lo, "uow_hi": hi, "counts": counts,
            "steps": meter["steps"] + 1}


def meter_psum(meter: Dict[str, jax.Array], axis_name: str):
    """Cross-shard aggregation (inside shard_map): the sync cost of hooks."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), meter)


def read_meter(meter) -> Dict[str, np.ndarray]:
    """Host-side readback of the device meter.  Each readback publishes the
    unit-of-work totals to the ``meter.*`` gauges (one gauge write per
    readback, not per step — readbacks are how UoW leaves the device)."""
    uow = meter_value(meter)
    steps = int(meter["steps"])
    m = obs.metrics()
    m.record("meter.uow_total", float(uow))
    m.record("meter.steps", steps)
    if steps:
        m.record("meter.uow_per_step", uow / steps)
    return {
        "uow": np.uint64(uow),
        "counts": np.asarray(meter["counts"]),
        "steps": steps,
    }
