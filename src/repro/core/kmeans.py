"""k-means++ / Lloyd / silhouette, in numpy (no sklearn dependency).

Used by the K-means selector (paper §IV-B1: silhouette-selected k <= 50,
cluster-size weights, SimPoint-style random projection of BBVs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator
                   ) -> np.ndarray:
    n = x.shape[0]
    centers = np.empty((k, x.shape[1]), x.dtype)
    idx = rng.integers(n)
    centers[0] = x[idx]
    d2 = np.sum((x - centers[0]) ** 2, axis=1)
    for i in range(1, k):
        total = d2.sum()
        if total <= 0 or not np.isfinite(total):
            idx = rng.integers(n)            # degenerate: identical points
        else:
            idx = rng.choice(n, p=d2 / total)
        centers[i] = x[idx]
        d2 = np.minimum(d2, np.sum((x - centers[i]) ** 2, axis=1))
    return centers


def lloyd(x: np.ndarray, centers: np.ndarray, iters: int = 50
          ) -> Tuple[np.ndarray, np.ndarray, float]:
    k = centers.shape[0]
    assign = np.zeros(x.shape[0], np.int64)
    for _ in range(iters):
        d2 = (np.sum(x * x, 1)[:, None] - 2 * x @ centers.T
              + np.sum(centers * centers, 1)[None])
        new_assign = np.argmin(d2, axis=1)
        if np.array_equal(new_assign, assign) and _ > 0:
            break
        assign = new_assign
        for c in range(k):
            m = assign == c
            if m.any():
                centers[c] = x[m].mean(axis=0)
    inertia = float(np.sum((x - centers[assign]) ** 2))
    return assign, centers, inertia


def kmeans(x: np.ndarray, k: int, *, seed: int = 0, restarts: int = 3
           ) -> Tuple[np.ndarray, np.ndarray, float]:
    rng = np.random.default_rng(seed)
    best = None
    for _ in range(restarts):
        c0 = kmeans_pp_init(x, k, rng)
        assign, centers, inertia = lloyd(x, c0.copy())
        if best is None or inertia < best[2]:
            best = (assign, centers, inertia)
    return best


def silhouette(x: np.ndarray, assign: np.ndarray,
               max_points: int = 1500, seed: int = 0) -> float:
    """Mean silhouette; subsampled for O(n^2) tractability."""
    n = x.shape[0]
    k = int(assign.max()) + 1
    if k < 2 or n < 3:
        return -1.0
    rng = np.random.default_rng(seed)
    if n > max_points:
        sel = rng.choice(n, max_points, replace=False)
    else:
        sel = np.arange(n)
    xs, asg = x[sel], assign[sel]
    d = np.sqrt(np.maximum(
        np.sum(xs * xs, 1)[:, None] - 2 * xs @ xs.T + np.sum(xs * xs, 1)[None],
        0.0))
    s_vals = []
    for i in range(len(sel)):
        same = asg == asg[i]
        same[i] = False
        a = d[i][same].mean() if same.any() else 0.0
        b = np.inf
        for c in range(k):
            if c == asg[i]:
                continue
            m = asg == c
            if m.any():
                b = min(b, d[i][m].mean())
        if not np.isfinite(b):
            continue
        s_vals.append((b - a) / max(a, b, 1e-30))
    return float(np.mean(s_vals)) if s_vals else -1.0


def random_projection(x: np.ndarray, dim: int = 15, seed: int = 0
                      ) -> np.ndarray:
    """SimPoint-style BBV dimensionality reduction."""
    if x.shape[1] <= dim:
        return x
    rng = np.random.default_rng(seed)
    proj = rng.normal(size=(x.shape[1], dim)) / np.sqrt(dim)
    return x @ proj


def pick_k_silhouette(x: np.ndarray, max_k: int = 50, seed: int = 0
                      ) -> Tuple[int, np.ndarray, np.ndarray]:
    """Silhouette-scored k selection (paper: #clusters <= 50)."""
    n = x.shape[0]
    ks = sorted(set(min(k, n - 1) for k in
                    [2, 3, 4, 6, 8, 12, 16, 24, 32, 50] if k < n))
    best = None
    for k in ks:
        if k > max_k or k < 2:
            continue
        assign, centers, _ = kmeans(x, k, seed=seed)
        score = silhouette(x, assign, seed=seed)
        if best is None or score > best[0]:
            best = (score, k, assign, centers)
    if best is None:
        assign, centers, _ = kmeans(x, min(2, n), seed=seed)
        return min(2, n), assign, centers
    return best[1], best[2], best[3]
