"""k-means++ / Lloyd / silhouette, in numpy (no sklearn dependency).

Used by the K-means selector (paper §IV-B1: silhouette-selected k <= 50,
cluster-size weights, SimPoint-style random projection of BBVs).

The Lloyd centroid update and the silhouette score are fully vectorized
(flattened ``bincount`` for per-cluster sums; one distance-matrix matmul
against cluster indicators for per-cluster mean distances), and the
silhouette k-sweep can fan out over a thread pool (numpy releases the GIL;
every candidate k is seeded independently, so the parallel sweep picks the
same k as the sequential one).
"""
from __future__ import annotations

import concurrent.futures
import os
from typing import Optional, Tuple

import numpy as np


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator
                   ) -> np.ndarray:
    n = x.shape[0]
    centers = np.empty((k, x.shape[1]), x.dtype)
    idx = rng.integers(n)
    centers[0] = x[idx]
    d2 = np.sum((x - centers[0]) ** 2, axis=1)
    for i in range(1, k):
        total = d2.sum()
        if total <= 0 or not np.isfinite(total):
            idx = rng.integers(n)            # degenerate: identical points
        else:
            idx = rng.choice(n, p=d2 / total)
        centers[i] = x[idx]
        d2 = np.minimum(d2, np.sum((x - centers[i]) ** 2, axis=1))
    return centers


def lloyd(x: np.ndarray, centers: np.ndarray, iters: int = 50
          ) -> Tuple[np.ndarray, np.ndarray, float]:
    k = centers.shape[0]
    assign = np.zeros(x.shape[0], np.int64)
    for _ in range(iters):
        d2 = (np.sum(x * x, 1)[:, None] - 2 * x @ centers.T
              + np.sum(centers * centers, 1)[None])
        new_assign = np.argmin(d2, axis=1)
        if np.array_equal(new_assign, assign) and _ > 0:
            break
        assign = new_assign
        # vectorized centroid update: per-cluster sums via one flattened
        # bincount (deterministic index-order accumulation, no np.add.at);
        # empty clusters keep their previous center
        dim = x.shape[1]
        cnt = np.bincount(assign, minlength=k)
        sums = np.bincount(
            (assign[:, None] * dim + np.arange(dim)[None, :]).ravel(),
            weights=x.ravel(), minlength=k * dim).reshape(k, dim)
        nonempty = cnt > 0
        centers[nonempty] = sums[nonempty] / cnt[nonempty, None]
    inertia = float(np.sum((x - centers[assign]) ** 2))
    return assign, centers, inertia


def kmeans(x: np.ndarray, k: int, *, seed: int = 0, restarts: int = 3
           ) -> Tuple[np.ndarray, np.ndarray, float]:
    rng = np.random.default_rng(seed)
    best = None
    for _ in range(restarts):
        c0 = kmeans_pp_init(x, k, rng)
        assign, centers, inertia = lloyd(x, c0.copy())
        if best is None or inertia < best[2]:
            best = (assign, centers, inertia)
    return best


def silhouette(x: np.ndarray, assign: np.ndarray,
               max_points: int = 1500, seed: int = 0) -> float:
    """Mean silhouette; subsampled for O(n^2) tractability."""
    n = x.shape[0]
    k = int(assign.max()) + 1
    if k < 2 or n < 3:
        return -1.0
    rng = np.random.default_rng(seed)
    if n > max_points:
        sel = rng.choice(n, max_points, replace=False)
    else:
        sel = np.arange(n)
    xs, asg = x[sel], assign[sel]
    m = len(sel)
    d = np.sqrt(np.maximum(
        np.sum(xs * xs, 1)[:, None] - 2 * xs @ xs.T + np.sum(xs * xs, 1)[None],
        0.0))
    # per-(point, cluster) distance sums in one matmul against the cluster
    # indicator matrix; a_i divides by (own cluster size - 1) because
    # d[i, i] == 0 contributes nothing, b_i is the min mean distance to a
    # *different* non-empty cluster (empty / own clusters masked to inf)
    onehot = np.zeros((m, k))
    onehot[np.arange(m), asg] = 1.0
    cnt = onehot.sum(axis=0)
    sums = d @ onehot                                   # [m, k]
    own = cnt[asg]
    a = np.where(own > 1, sums[np.arange(m), asg] / np.maximum(own - 1, 1),
                 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        mean_d = sums / cnt[None, :]
    mean_d[:, cnt == 0] = np.inf
    mean_d[np.arange(m), asg] = np.inf
    b = mean_d.min(axis=1)
    valid = np.isfinite(b)                # point needs another non-empty cluster
    if not valid.any():
        return -1.0
    s = (b[valid] - a[valid]) / np.maximum(np.maximum(a[valid], b[valid]),
                                           1e-30)
    return float(np.mean(s))


def random_projection(x: np.ndarray, dim: int = 15, seed: int = 0
                      ) -> np.ndarray:
    """SimPoint-style BBV dimensionality reduction."""
    if x.shape[1] <= dim:
        return x
    rng = np.random.default_rng(seed)
    proj = rng.normal(size=(x.shape[1], dim)) / np.sqrt(dim)
    return x @ proj


def _score_k(x: np.ndarray, k: int, seed: int
             ) -> Tuple[float, int, np.ndarray, np.ndarray]:
    assign, centers, _ = kmeans(x, k, seed=seed)
    return silhouette(x, assign, seed=seed), k, assign, centers


def pick_k_silhouette(x: np.ndarray, max_k: int = 50, seed: int = 0,
                      n_workers: Optional[int] = None
                      ) -> Tuple[int, np.ndarray, np.ndarray]:
    """Silhouette-scored k selection (paper: #clusters <= 50).

    Candidate ks are scored independently (each k re-seeds its own rng), so
    the sweep fans out over a thread pool; the winner is picked by walking
    the candidates in ascending-k order with a strict ``>`` — identical to
    the sequential sweep no matter the completion order.  ``n_workers=1``
    forces the sequential path.
    """
    n = x.shape[0]
    ks = [k for k in sorted(set(min(k, n - 1) for k in
                                [2, 3, 4, 6, 8, 12, 16, 24, 32, 50]
                                if k < n))
          if 2 <= k <= max_k]
    if not ks:
        assign, centers, _ = kmeans(x, min(2, n), seed=seed)
        return min(2, n), assign, centers
    workers = n_workers or min(len(ks), os.cpu_count() or 1)
    if workers > 1:
        with concurrent.futures.ThreadPoolExecutor(max_workers=workers) as ex:
            scored = list(ex.map(lambda k: _score_k(x, k, seed), ks))
    else:
        scored = [_score_k(x, k, seed) for k in ks]
    best = scored[0]
    for cand in scored[1:]:
        if cand[0] > best[0]:
            best = cand
    return best[1], best[2], best[3]
