"""On-disk profile artifacts: interval profiles, selections, nuggets, replay
results.  Directory layout::

    <dir>/profile.npz      # bbvs, stamps, uows, markers, dyn history
    <dir>/table.json       # BlockTable
    <dir>/meta.json        # interval size, totals
    <dir>/nuggets_<m>.json # per selection method
    <dir>/results_<m>_<platform>.json
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core.intervals import Interval, Marker, Profile
from repro.core.registry import BlockTable


def save_profile(dirpath: str, profile: Profile) -> None:
    os.makedirs(dirpath, exist_ok=True)
    ivs = profile.intervals
    np.savez_compressed(
        os.path.join(dirpath, "profile.npz"),
        bbvs=np.stack([iv.bbv for iv in ivs]) if ivs else np.zeros((0, 0)),
        stamps=np.stack([iv.stamps for iv in ivs]) if ivs else np.zeros((0, 0)),
        hits_at=np.stack([iv.hits_at_stamp for iv in ivs]) if ivs else np.zeros((0, 0)),
        start_uow=np.array([iv.start_uow for iv in ivs]),
        end_uow=np.array([iv.end_uow for iv in ivs]),
        start_step=np.array([iv.start_step for iv in ivs]),
        end_step=np.array([iv.end_step for iv in ivs]),
        marker_block=np.array([iv.end_marker.block for iv in ivs], np.int64),
        marker_hits=np.array([iv.end_marker.hits for iv in ivs], np.int64),
        marker_uow=np.array([iv.end_marker.uow for iv in ivs]),
        **{f"dyn_{k}": v for k, v in profile.dyn_history.items()},
    )
    with open(os.path.join(dirpath, "table.json"), "w") as f:
        json.dump(profile.table.to_json(), f)
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump({"interval_uow": profile.interval_uow,
                   "total_uow": profile.total_uow,
                   "n_steps": profile.n_steps,
                   "step_uow": profile.step_uow}, f)


def load_profile(dirpath: str) -> Profile:
    with open(os.path.join(dirpath, "table.json")) as f:
        table = BlockTable.from_json(json.load(f))
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    z = np.load(os.path.join(dirpath, "profile.npz"))
    n = len(z["start_uow"])
    intervals = []
    for i in range(n):
        intervals.append(Interval(
            idx=i,
            start_uow=float(z["start_uow"][i]),
            end_uow=float(z["end_uow"][i]),
            end_marker=Marker(int(z["marker_block"][i]),
                              int(z["marker_hits"][i]),
                              float(z["marker_uow"][i])),
            bbv=z["bbvs"][i],
            stamps=z["stamps"][i],
            hits_at_stamp=z["hits_at"][i],
            start_step=float(z["start_step"][i]),
            end_step=float(z["end_step"][i]),
        ))
    dyn = {k[4:]: z[k] for k in z.files if k.startswith("dyn_")}
    return Profile(table=table, interval_uow=meta["interval_uow"],
                   intervals=intervals, total_uow=meta["total_uow"],
                   n_steps=meta["n_steps"], step_uow=meta["step_uow"],
                   dyn_history=dyn)
