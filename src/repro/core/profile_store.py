"""On-disk profile artifacts: interval profiles, selections, nuggets, replay
results.  Directory layout::

    <dir>/profile.npz      # bbvs, stamps, uows, markers, dyn history
    <dir>/table.json       # BlockTable
    <dir>/meta.json        # interval size, totals
    <dir>/nuggets_<m>.json # per selection method
    <dir>/results_<m>_<platform>.json

Content-addressed profile cache (``cached_build`` / ``cached_finalize``)::

    <cache_dir>/<key>/     # one save_profile() directory per cache key

The cache key is the sha256 of everything the analysis depends on — the
canonical BlockTable JSON (sorted keys), the interval size, and a digest of
the step stream (per-step kind plus the raw bytes of every dynamic aux
array, keys sorted).  Profiling the same stream twice therefore loads the
stored Profile instead of re-analyzing; any change to the table, interval
size, step kinds or dyn values changes the key and misses.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.intervals import (Interval, IntervalBuilder, Marker, Profile,
                                  build_profile)
from repro.core.intervals_vec import Step
from repro.core.registry import BlockTable


def save_profile(dirpath: str, profile: Profile) -> None:
    os.makedirs(dirpath, exist_ok=True)
    ivs = profile.intervals
    nb = profile.table.n_blocks
    # zero-interval profiles keep the block dimension so a round trip
    # preserves bbv_matrix().shape == (0, n_blocks)
    np.savez_compressed(
        os.path.join(dirpath, "profile.npz"),
        bbvs=np.stack([iv.bbv for iv in ivs]) if ivs else np.zeros((0, nb)),
        stamps=np.stack([iv.stamps for iv in ivs]) if ivs else np.zeros((0, nb)),
        hits_at=np.stack([iv.hits_at_stamp for iv in ivs]) if ivs
        else np.zeros((0, nb), np.int64),
        start_uow=np.array([iv.start_uow for iv in ivs]),
        end_uow=np.array([iv.end_uow for iv in ivs]),
        start_step=np.array([iv.start_step for iv in ivs]),
        end_step=np.array([iv.end_step for iv in ivs]),
        marker_block=np.array([iv.end_marker.block for iv in ivs], np.int64),
        marker_hits=np.array([iv.end_marker.hits for iv in ivs], np.int64),
        marker_uow=np.array([iv.end_marker.uow for iv in ivs]),
        **{f"dyn_{k}": v for k, v in profile.dyn_history.items()},
    )
    with open(os.path.join(dirpath, "table.json"), "w") as f:
        json.dump(profile.table.to_json(), f)
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump({"interval_uow": profile.interval_uow,
                   "total_uow": profile.total_uow,
                   "n_steps": profile.n_steps,
                   "step_uow": profile.step_uow}, f)


def load_profile(dirpath: str) -> Profile:
    with open(os.path.join(dirpath, "table.json")) as f:
        table = BlockTable.from_json(json.load(f))
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    z = np.load(os.path.join(dirpath, "profile.npz"))
    # NpzFile members decompress on every [] access — pull each array out
    # exactly once before the per-interval loop
    bbvs, stamps, hits_at = z["bbvs"], z["stamps"], z["hits_at"]
    start_uow, end_uow = z["start_uow"].tolist(), z["end_uow"].tolist()
    start_step, end_step = z["start_step"].tolist(), z["end_step"].tolist()
    marker_block = z["marker_block"].tolist()
    marker_hits = z["marker_hits"].tolist()
    marker_uow = z["marker_uow"].tolist()
    intervals = []
    for i in range(len(start_uow)):
        intervals.append(Interval(
            idx=i,
            start_uow=start_uow[i],
            end_uow=end_uow[i],
            end_marker=Marker(marker_block[i], marker_hits[i],
                              marker_uow[i]),
            bbv=bbvs[i],
            stamps=stamps[i],
            hits_at_stamp=hits_at[i],
            start_step=start_step[i],
            end_step=end_step[i],
        ))
    dyn = {k[4:]: z[k] for k in z.files if k.startswith("dyn_")}
    return Profile(table=table, interval_uow=meta["interval_uow"],
                   intervals=intervals, total_uow=meta["total_uow"],
                   n_steps=meta["n_steps"], step_uow=meta["step_uow"],
                   dyn_history=dyn)


# ---------------------------------------------------------------------------
# content-addressed profile cache
# ---------------------------------------------------------------------------

def stream_digest(steps: Sequence[Step]) -> str:
    """sha256 of a step stream: per-step kind + dyn aux array bytes.

    Dyn dicts hash by sorted key with the value's canonical float64 bytes,
    so dict insertion order does not affect the digest.
    """
    h = hashlib.sha256()
    h.update(str(len(steps)).encode())
    for kind, dyn in steps:
        h.update(b"\x00")
        h.update(kind.encode())
        if dyn:
            for k in sorted(dyn):
                h.update(b"\x01")
                h.update(k.encode())
                v = np.ascontiguousarray(np.asarray(dyn[k], np.float64))
                h.update(str(v.shape).encode())
                h.update(v.tobytes())
    return h.hexdigest()


def profile_cache_key(table: BlockTable, interval_uow: float,
                      steps: Sequence[Step]) -> str:
    """Cache key = hash of everything the interval analysis depends on."""
    h = hashlib.sha256()
    h.update(json.dumps(table.to_json(), sort_keys=True).encode())
    h.update(repr(float(interval_uow)).encode())
    h.update(stream_digest(steps).encode())
    return h.hexdigest()


def cached_build(cache_dir: str, table: BlockTable, interval_uow: float,
                 steps: Sequence[Step], *, method: str = "batch",
                 **kwargs) -> Tuple[Profile, bool]:
    """Build (or load) the Profile of a step stream; returns (profile, hit).

    On a miss the profile is analyzed with :func:`build_profile` and saved
    under ``<cache_dir>/<key>``; on a hit it is loaded from there without
    re-analysis.
    """
    key = profile_cache_key(table, interval_uow, steps)
    path = os.path.join(cache_dir, key)
    if os.path.exists(os.path.join(path, "meta.json")):
        return load_profile(path), True
    profile = build_profile(table, interval_uow, steps, method=method,
                            **kwargs)
    save_profile(path, profile)
    return profile, False


def cached_finalize(cache_dir: str, builder: IntervalBuilder, *,
                    max_workers: Optional[int] = None,
                    chunk_steps: Optional[int] = None
                    ) -> Tuple[Profile, bool]:
    """Cache-aware ``finalize()`` for a builder that logged its steps.

    Uses ``builder.step_log`` as the cache key input; most useful with
    ``IntervalBuilder(..., defer=True)``, where a hit skips the entire
    batch analysis.  ``max_workers > 1`` analyzes a miss through the
    sharded ``finalize_parallel`` path (bit-for-bit identical profile, so
    serial and parallel runs share cache entries).
    """
    key = profile_cache_key(builder.table, builder.interval_uow,
                            builder.step_log)
    path = os.path.join(cache_dir, key)
    if os.path.exists(os.path.join(path, "meta.json")):
        return load_profile(path), True
    if max_workers is not None and max_workers > 1:
        profile = builder.finalize_parallel(chunk_steps=chunk_steps,
                                            max_workers=max_workers)
    else:
        profile = builder.finalize()
    save_profile(path, profile)
    return profile, False
