"""Block registry: the IRBB analogue (DESIGN.md §2).

A *block* is an instrumented unit of the step program (embed, attention
layer, MoE router, expert, SSD scan, head/loss …).  The :class:`BlockTable`
records, per block, its static IR cost (jaxpr ops per execution) and the
step *program*: the ordered hook stream one step produces.  Dense-arch step
programs are static (XLA programs have static shapes); data-dependence enters
through *virtual* signature blocks (expert token occupancy, sequence-length
bins) that enrich the interval signature exactly like input-driven control
flow enriches the paper's BBVs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockDef:
    name: str
    cost_ops: float                  # IR ops per execution (unit of work)
    cost_flops: float = 0.0
    virtual: bool = False            # signature-only (not in the hook stream)
    dyn_key: Optional[str] = None    # aux-dict key feeding a virtual block
    dyn_index: int = -1              # index into the aux vector (-1 = scalar)


@dataclasses.dataclass(frozen=True)
class Segment:
    """``repeat`` consecutive executions of ``pattern`` (list of block ids)."""
    pattern: Tuple[int, ...]
    repeat: int


@dataclasses.dataclass
class BlockTable:
    """Blocks + one hook-stream *program* per step kind.

    Homogeneous workloads (training) have one "default" program; serving has
    heterogeneous steps (prefill vs decode) with different streams over a
    shared block id space (see ``merge_tables``).
    """
    blocks: List[BlockDef]
    program: List[Segment]                       # "default" step kind
    programs: Optional[Dict[str, List[Segment]]] = None

    # ---- derived ----------------------------------------------------------
    def __post_init__(self):
        self._expand_cache: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        self._expand_count: Dict[str, int] = {}   # actual expansions, per kind
        self._counts_cache: Dict[str, np.ndarray] = {}
        self._occ_cache: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        if self.programs is None:
            self.programs = {}
        if "default" not in self.programs:
            self.programs["default"] = self.program

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def names(self) -> List[str]:
        return [b.name for b in self.blocks]

    def id_of(self, name: str) -> int:
        for i, b in enumerate(self.blocks):
            if b.name == name:
                return i
        raise KeyError(name)

    def costs(self) -> np.ndarray:
        return np.array([b.cost_ops for b in self.blocks], np.float64)

    def kinds(self) -> List[str]:
        return list(self.programs)

    def expand(self, kind: str = "default") -> Tuple[np.ndarray, np.ndarray]:
        """One step's hook stream -> (block_ids [M], cum_uow [M]).

        cum_uow[i] is the global-counter increment *after* hook i fires
        (i.e. the count-stamp the paper's hook would record), relative to
        the start of the step.  Expansions are memoized per kind (the
        stream is static); ``_expand_count`` records how many times each
        kind was actually materialized (regression-tested to stay at 1).
        """
        if kind in self._expand_cache:
            return self._expand_cache[kind]
        self._expand_count[kind] = self._expand_count.get(kind, 0) + 1
        ids: List[int] = []
        for seg in self.programs[kind]:
            ids.extend(list(seg.pattern) * seg.repeat)
        ids_arr = np.asarray(ids, np.int64)
        costs = self.costs()[ids_arr]
        cum = np.cumsum(costs)
        self._expand_cache[kind] = (ids_arr, cum)
        return self._expand_cache[kind]

    def expand_all(self) -> None:
        """Materialize every kind's stream, counts and occurrence structure
        (thread-safety warmup: worker threads then only read the caches)."""
        for kind in self.programs:
            self.expand(kind)
            self.step_counts(kind)
            self.step_occ(kind)

    def step_uow(self, kind: str = "default") -> float:
        _, cum = self.expand(kind)
        return float(cum[-1]) if len(cum) else 0.0

    def step_counts(self, kind: str = "default") -> np.ndarray:
        """Static per-step execution count of every (non-virtual) block."""
        if kind not in self._counts_cache:
            ids, _ = self.expand(kind)
            self._counts_cache[kind] = np.bincount(
                ids, minlength=self.n_blocks).astype(np.int64)
        return self._counts_cache[kind]

    def step_occ(self, kind: str = "default"
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Static within-step occurrence structure of one kind's stream:
        ``(occ, cnt_gather)`` where ``occ[i]`` is the 1-based rank of hook
        ``i`` among executions of its block within one step and
        ``cnt_gather[i]`` is that block's total per-step count.  A step
        ``s`` of a same-kind run then has global cumulative hit counts
        ``base + s * cnt_gather + occ`` — the vectorized batch analyzer's
        sort-free hit computation.  Cached per kind (streams are static).
        """
        if kind not in self._occ_cache:
            ids, _ = self.expand(kind)
            m = len(ids)
            occ = np.empty(m, np.int64)
            if m:
                order = np.argsort(ids, kind="stable")
                sid = ids[order]
                new = np.empty(m, bool)
                new[0] = True
                new[1:] = sid[1:] != sid[:-1]
                starts = np.flatnonzero(new)
                glen = np.diff(np.append(starts, m))
                occ[order] = np.arange(m) - np.repeat(starts, glen) + 1
            self._occ_cache[kind] = (occ, self.step_counts(kind)[ids])
        return self._occ_cache[kind]

    def virtual_ids(self) -> List[int]:
        return [i for i, b in enumerate(self.blocks) if b.virtual]

    def to_json(self) -> Dict:
        return {
            "blocks": [dataclasses.asdict(b) for b in self.blocks],
            "program": [{"pattern": list(s.pattern), "repeat": s.repeat}
                        for s in self.program],
            "programs": {k: [{"pattern": list(s.pattern), "repeat": s.repeat}
                             for s in v] for k, v in (self.programs or {}).items()},
        }

    @staticmethod
    def from_json(d: Dict) -> "BlockTable":
        progs = {k: [Segment(tuple(s["pattern"]), s["repeat"]) for s in v]
                 for k, v in d.get("programs", {}).items()} or None
        return BlockTable(
            [BlockDef(**b) for b in d["blocks"]],
            [Segment(tuple(s["pattern"]), s["repeat"]) for s in d["program"]],
            progs,
        )


def merge_tables(tables: Dict[str, BlockTable]) -> BlockTable:
    """Merge per-kind tables into one shared block id space; block names get
    a ``<kind>/`` prefix (prefill attention is a different IRBB than decode
    attention — different code paths, different IR size)."""
    blocks: List[BlockDef] = []
    programs: Dict[str, List[Segment]] = {}
    for kind, t in tables.items():
        offset = len(blocks)
        for b in t.blocks:
            blocks.append(dataclasses.replace(b, name=f"{kind}/{b.name}"))
        programs[kind] = [
            Segment(tuple(p + offset for p in s.pattern), s.repeat)
            for s in t.program]
    first = next(iter(programs.values()))
    return BlockTable(blocks, first, programs)
