"""Sample-selection methodologies (paper §IV-B1).

The framework is selector-agnostic (the paper's point); three built-ins:

- ``RandomSelector``  — uniform interval sampling, equal weights [49/SMARTS-
  style statistical baseline].
- ``KMeansSelector``  — k-means over (normalized, random-projected) BBVs with
  silhouette-selected k <= 50 and cluster-size weights [SimPoint lineage].
- ``SystematicSelector`` — every n-th interval (periodic systematic sampling).

Each returns a :class:`Selection`: representative interval ids + weights
(weights sum to 1 over the whole run).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.intervals import Profile
from repro.core.kmeans import (kmeans, pick_k_silhouette, random_projection,
                               silhouette)


@dataclasses.dataclass
class Selection:
    method: str
    interval_ids: List[int]
    weights: np.ndarray              # per selected interval, sums to 1
    assignment: Optional[np.ndarray] = None   # cluster id per interval

    def to_json(self):
        return {"method": self.method,
                "interval_ids": [int(i) for i in self.interval_ids],
                "weights": self.weights.tolist(),
                "assignment": (self.assignment.tolist()
                               if self.assignment is not None else None)}

    @staticmethod
    def from_json(d):
        return Selection(d["method"], d["interval_ids"],
                         np.asarray(d["weights"]),
                         np.asarray(d["assignment"])
                         if d.get("assignment") is not None else None)


def normalize_bbvs(profile: Profile) -> np.ndarray:
    x = profile.bbv_matrix().astype(np.float64)
    row = x.sum(axis=1, keepdims=True)
    row[row == 0] = 1.0
    return x / row


class RandomSelector:
    def __init__(self, n_samples: int = 50, seed: int = 0):
        self.n_samples, self.seed = n_samples, seed

    def select(self, profile: Profile) -> Selection:
        n = profile.n_intervals
        rng = np.random.default_rng(self.seed)
        k = min(self.n_samples, n)
        ids = sorted(rng.choice(n, k, replace=False).tolist())
        w = np.full(k, 1.0 / k)
        return Selection("random", ids, w)


class SystematicSelector:
    def __init__(self, n_samples: int = 50, offset: int = 0):
        self.n_samples, self.offset = n_samples, offset

    def select(self, profile: Profile) -> Selection:
        n = profile.n_intervals
        k = min(self.n_samples, n)
        stride = max(1, n // k)
        ids = list(range(self.offset % stride, n, stride))[:k]
        w = np.full(len(ids), 1.0 / len(ids))
        return Selection("systematic", ids, w)


class KMeansSelector:
    def __init__(self, max_k: int = 50, seed: int = 0, project_dim: int = 15,
                 fixed_k: Optional[int] = None,
                 n_workers: Optional[int] = None):
        self.max_k, self.seed, self.project_dim = max_k, seed, project_dim
        self.fixed_k = fixed_k
        self.n_workers = n_workers       # thread-pool width for the k-sweep

    def select(self, profile: Profile) -> Selection:
        x = normalize_bbvs(profile)
        xp = random_projection(x, self.project_dim, self.seed)
        n = xp.shape[0]
        if self.fixed_k is not None:
            k = min(self.fixed_k, n)
            assign, centers, _ = kmeans(xp, k, seed=self.seed)
        else:
            k, assign, centers = pick_k_silhouette(
                xp, self.max_k, self.seed, n_workers=self.n_workers)
        ids, weights = [], []
        for c in range(k):
            members = np.nonzero(assign == c)[0]
            if len(members) == 0:
                continue
            d2 = np.sum((xp[members] - centers[c]) ** 2, axis=1)
            ids.append(int(members[np.argmin(d2)]))
            weights.append(len(members) / n)
        order = np.argsort(ids)
        ids = [ids[i] for i in order]
        weights = np.asarray([weights[i] for i in order])
        return Selection("kmeans", ids, weights, assignment=assign)


SELECTORS = {
    "random": RandomSelector,
    "kmeans": KMeansSelector,
    "systematic": SystematicSelector,
}
