"""Nugget-for-JAX: the paper's portable targeted-sampling framework.

Pipeline (paper Fig. 1):
  preparation  -> BlockTable (blocks_lm.build_block_table)
  analysis     -> WorkMeter hooks + IntervalBuilder -> Profile
  selection    -> select.{Random,KMeans,Systematic}Selector -> Selection
  creation     -> nugget.create_nuggets (markers incl. low-overhead search)
  validation   -> replay.ReplayEngine + validate.* (native, cross-platform)
"""
from repro.core.unit_of_work import IRCost, jaxpr_cost, trace_cost  # noqa: F401
from repro.core.registry import BlockDef, BlockTable, Segment  # noqa: F401
from repro.core.blocks_lm import build_block_table  # noqa: F401
from repro.core.meter import (  # noqa: F401
    init_meter, materialize_dyn, meter_value, read_meter, read_meters,
    tick_step,
)
from repro.core.intervals import (  # noqa: F401
    Interval, IntervalBuilder, Marker, Profile, build_profile,
    build_profile_from_steps, build_profile_parallel,
)
from repro.core.intervals_vec import (  # noqa: F401
    ChunkResult, analyze_steps, analyze_steps_parallel, as_steps,
)
from repro.core.select import (  # noqa: F401
    KMeansSelector, RandomSelector, Selection, SystematicSelector, SELECTORS,
)
from repro.core.markers import (  # noqa: F401
    MarkerPlan, low_overhead_marker, marker_hook_fraction, plan_markers,
)
from repro.core.nugget import Nugget, create_nuggets, load_nuggets, save_nuggets  # noqa: F401
from repro.core.replay import ReplayEngine, ReplayResult, SimpleRunner, measure_full_run  # noqa: F401
from repro.core.validate import (  # noqa: F401
    PlatformResult, consistency_report, full_run_baseline, nugget_variability,
    platform_results, predict_total_time, prediction_error,
    signature_divergence, speedup_error_matrix, validation_report,
)
from repro.core.profile_store import (  # noqa: F401
    cached_build, cached_finalize, load_profile, profile_cache_key,
    save_profile, stream_digest,
)
from repro.core import hlo_analysis  # noqa: F401
