"""Serving launcher (batched requests, continuous batching).

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 16
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile-out")
    ap.add_argument("--profile-cache",
                    help="content-addressed profile cache directory")
    ap.add_argument("--no-defer-analysis", action="store_true",
                    help="legacy per-step interval analysis (the default "
                         "defers: log steps while serving, batch-analyze "
                         "at the end with the vectorized path)")
    ap.add_argument("--store",
                    help="ArtifactStore root: persist the profile as a "
                         "content-addressed pipeline artifact")
    args = ap.parse_args()

    import jax

    from repro import obs
    obs.log.setup()                       # key=value lines, REPRO_LOG_LEVEL
    obs.configure_from_env()              # spans if REPRO_TRACE is set

    from repro.configs import get_config, reduced
    from repro.models.model_zoo import build_model
    from repro.serve import ServeEngine, SyntheticRequests

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, n_layers=4, d_model=128, d_ff=256, vocab=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, batch=args.batch, max_seq=args.max_seq,
                      prefill_len=args.prefill_len,
                      temperature=args.temperature, seed=args.seed,
                      defer_analysis=not args.no_defer_analysis)
    gen = SyntheticRequests(cfg.vocab_size, prompt_len=args.prefill_len,
                            mean_new=24, seed=args.seed)
    stats = eng.run(params, [gen.request(i) for i in range(args.requests)])
    print(json.dumps(stats, indent=1))
    if args.profile_out or args.profile_cache or args.store:
        import dataclasses

        from repro.pipeline import persist_profile_cli
        persist_profile_cli(
            eng.builder, profile_out=args.profile_out,
            profile_cache=args.profile_cache, store=args.store,
            spec={"arch": dataclasses.asdict(cfg), "kind": "serve",
                  "requests": args.requests, "batch": args.batch,
                  "max_seq": args.max_seq, "prefill_len": args.prefill_len,
                  "temperature": args.temperature, "seed": args.seed})


if __name__ == "__main__":
    main()
