"""Single entrypoint for the end-to-end sampling pipeline.

Runs profile -> select -> mark -> replay -> validate against a
content-addressed artifact store and emits a JSON run manifest (stage
timings, cache hits, artifact digests, prediction/speedup errors).
Re-running with the same flags hits the cache for every stage; changing
only ``--selector`` re-runs selection and downstream stages while the
profile and baseline artifacts are reused.

With ``--trace DIR`` the run is traced end to end: ``DIR/trace.json`` is a
Chrome-trace/Perfetto file (one span per stage, load it at
https://ui.perfetto.dev), ``DIR/trace.jsonl`` the raw event stream and
``DIR/metrics.json`` the metrics snapshot that is also embedded in the
manifest's ``obs`` block.  Summarize later with
``python -m repro.launch.obs DIR``.

Examples:
    PYTHONPATH=src python -m repro.launch.pipeline --arch olmoe-1b-7b \
        --reduced --steps 16 --selector kmeans --platforms f32,bf16 \
        --store /tmp/artifacts --manifest-out /tmp/manifest.json \
        --trace /tmp/run-trace
"""
from __future__ import annotations

import argparse
import json
import os


def build_config(args) -> "PipelineConfig":
    from repro.pipeline import PipelineConfig
    if args.selector == "random":
        selector_args = {"n_samples": args.n_samples,
                         "seed": args.selector_seed}
    elif args.selector == "systematic":
        selector_args = {"n_samples": args.n_samples}
    else:                                   # kmeans
        selector_args = {"seed": args.selector_seed}
        if args.fixed_k:
            selector_args["fixed_k"] = args.fixed_k
    return PipelineConfig(
        arch=args.arch,
        platforms=tuple(p for p in args.platforms.split(",") if p),
        selector=args.selector,
        selector_args=selector_args,
        steps=args.steps, seq_len=args.seq_len, batch=args.batch,
        interval_steps=args.interval_steps, seed=args.seed,
        reduce=args.reduced,
        warmup_intervals=args.warmup_intervals,
        search_distance=args.search_distance,
        ckpt_every=args.ckpt_every,
        defer_analysis=not args.no_defer_analysis,
        profile_platform=args.profile_platform,
        workers=0 if args.serial else args.workers,
        max_attempts=args.max_attempts,
        retry_backoff_s=args.retry_backoff,
        stage_timeout_s=args.stage_timeout,
        gc_orphans=not args.no_gc,
    )


def main():
    ap = argparse.ArgumentParser(
        description="artifact-driven profile/select/mark/replay/validate run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--interval-steps", type=float, default=2.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--selector", default="kmeans",
                    choices=("random", "kmeans", "systematic"))
    ap.add_argument("--n-samples", type=int, default=6,
                    help="sample count for random/systematic selectors")
    ap.add_argument("--selector-seed", type=int, default=0)
    ap.add_argument("--fixed-k", type=int, default=0,
                    help="k-means: skip the silhouette sweep, use this k")
    ap.add_argument("--platforms", default="f32,bf16",
                    help="comma-separated platform tokens "
                         "(f32, bf16, f32-ref, bf16-chunk16, ...)")
    ap.add_argument("--profile-platform",
                    help="platform to profile on (default: first)")
    ap.add_argument("--warmup-intervals", type=int, default=1)
    ap.add_argument("--search-distance", type=float, default=0.0,
                    help="low-overhead marker search distance (UoW)")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--no-defer-analysis", action="store_true",
                    help="legacy per-step interval analysis instead of the "
                         "deferred vectorized batch path")
    ap.add_argument("--workers", type=int, default=0,
                    help="DAG scheduler worker threads: ready stages run "
                         "concurrently and profiling shards across this "
                         "many analysis threads (0/1 = serial; artifact "
                         "digests are identical either way)")
    ap.add_argument("--serial", action="store_true",
                    help="force the serial stage loop (same as --workers 0)")
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="stage attempts before a transient failure is "
                         "fatal (exponential backoff, deterministic jitter)")
    ap.add_argument("--retry-backoff", type=float, default=0.05,
                    metavar="S", help="base retry backoff seconds")
    ap.add_argument("--stage-timeout", type=float, default=None,
                    metavar="S", help="per-attempt stage wall-clock budget "
                    "(breach raises StageTimeout and retries)")
    ap.add_argument("--no-gc", action="store_true",
                    help="keep orphaned uncommitted artifact dirs instead "
                         "of gc'ing them at run start (use when other "
                         "pipelines share this store concurrently)")
    ap.add_argument("--faults", metavar="SPEC",
                    help="fault-injection spec (see docs/robustness.md), "
                         "e.g. 'raise:stage=profile,p=0.3;kill:n=1'; "
                         "defaults to $REPRO_FAULTS")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="deterministic seed for --faults decisions")
    ap.add_argument("--store", default="/tmp/repro-artifacts",
                    help="content-addressed artifact store root")
    ap.add_argument("--manifest-out",
                    help="also write the run manifest JSON to this path")
    ap.add_argument("--trace", metavar="DIR",
                    help="trace the run: write Chrome-trace trace.json, "
                         "raw trace.jsonl and metrics.json under DIR")
    ap.add_argument("--report", action="store_true",
                    help="print the human metrics table after the run")
    args = ap.parse_args()

    from repro import obs
    obs.log.setup()
    if args.trace:
        obs.configure(trace=True, trace_dir=args.trace)
    else:
        obs.configure_from_env()

    from repro.faults import FaultInjector
    from repro.pipeline import Pipeline

    if args.faults:
        injector = FaultInjector.from_spec(args.faults, seed=args.fault_seed)
    else:
        injector = FaultInjector.from_env()
    if injector is not None:
        obs.log.kv("fault_injection_enabled", logger="launch.pipeline",
                   rules=len(injector.rules), seed=injector.seed)

    manifest = Pipeline(build_config(args), args.store,
                        fault_injector=injector).run()
    if args.trace:
        tr = obs.tracer()
        trace_json = tr.write_chrome(os.path.join(args.trace, "trace.json"))
        obs.metrics().write_json(os.path.join(args.trace, "metrics.json"))
        tr.close()
        manifest["obs"]["trace_json"] = trace_json
        obs.log.kv("trace_written", logger="launch.pipeline",
                   path=trace_json, events=len(tr.events()))
    out = json.dumps(manifest, indent=1, default=str)
    print(out)
    if args.manifest_out:
        with open(args.manifest_out, "w") as f:
            f.write(out)
        obs.log.kv("manifest_written", logger="launch.pipeline",
                   path=args.manifest_out)
    if args.report:
        print(obs.metrics().report())


if __name__ == "__main__":
    main()
