"""Training launcher.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 50 --ckpt-dir /tmp/ck --profile-out /tmp/prof
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-feasible)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--interval-steps", type=float, default=2.0)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--no-instrument", action="store_true")
    ap.add_argument("--profile-out")
    ap.add_argument("--profile-cache",
                    help="content-addressed profile cache directory: "
                         "identical (table, interval, step stream) runs "
                         "load the stored profile instead of re-analyzing")
    ap.add_argument("--no-defer-analysis", action="store_true",
                    help="legacy per-step interval analysis (the default "
                         "defers: log steps during training, batch-analyze "
                         "at the end with the vectorized path)")
    ap.add_argument("--store",
                    help="ArtifactStore root: persist the profile as a "
                         "content-addressed pipeline artifact")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro import obs
    obs.log.setup()                       # key=value lines, REPRO_LOG_LEVEL
    obs.configure_from_env()              # spans if REPRO_TRACE is set

    from repro.configs import get_config, reduced
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import linear_warmup_cosine
    from repro.train import Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, n_layers=4, d_model=128, d_ff=256, vocab=1024,
                      seq=args.seq_len)
    tr = Trainer(cfg, seq_len=args.seq_len, batch=args.batch,
                 opt=AdamWConfig(lr=args.lr),
                 lr_fn=linear_warmup_cosine(args.lr, args.steps // 10 + 1,
                                            args.steps),
                 seed=args.seed,
                 instrument=not args.no_instrument,
                 interval_steps=args.interval_steps,
                 microbatch=args.microbatch,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 defer_analysis=not args.no_defer_analysis)
    state = tr.run(args.steps, log_every=args.log_every)
    print(json.dumps({
        "final_loss": tr.metrics_history[-1]["loss"],
        "mean_step_s": sum(tr.step_times[1:]) / max(len(tr.step_times) - 1, 1),
        "stragglers": tr.watchdog_report().slow_steps,
    }, indent=1))
    if (args.profile_out or args.profile_cache or args.store) \
            and not args.no_instrument:
        import dataclasses

        from repro.pipeline import persist_profile_cli
        persist_profile_cli(
            tr.builder, profile_out=args.profile_out,
            profile_cache=args.profile_cache, store=args.store,
            spec={"arch": dataclasses.asdict(cfg), "kind": "train",
                  "seq_len": args.seq_len, "batch": args.batch,
                  "steps": args.steps, "seed": args.seed,
                  "interval_steps": args.interval_steps})


if __name__ == "__main__":
    main()
