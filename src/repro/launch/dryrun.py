import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation) on the production meshes, and record
memory/cost/collective analyses for the roofline (EXPERIMENTS.md §Dry-run).

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all          # every cell, subprocess-per-cell
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np


# activation-memory-driven gradient-accumulation factors (global batch 256)
MICROBATCH = {
    "mistral-large-123b": 64,
    "internvl2-76b": 64,
    "llama4-scout-17b-a16e": 16,
    "qwen2.5-14b": 16,
    "gemma3-4b": 8,
    "qwen3-1.7b": 4,
    "mamba2-780m": 8,
    "zamba2-1.2b": 8,
    "olmoe-1b-7b": 4,
    "whisper-tiny": 1,
}

V5E = {"flops_bf16": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9, "hbm_gb": 16}


def cell_id(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def _tree_bytes_per_device(struct_tree, shardings) -> int:
    import jax
    total = 0.0
    for s, sh in zip(jax.tree.leaves(struct_tree),
                     jax.tree.leaves(shardings,
                                     is_leaf=lambda x: hasattr(x, "spec"))):
        shape = sh.shard_shape(s.shape)
        itemsize = 0.5 if "int4" in str(s.dtype) else s.dtype.itemsize
        total += float(np.prod(shape)) * itemsize
    return int(total)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, instrument: bool = True, causal_skip: bool = False,
             remat: Optional[str] = None,
             attn_chunk: Optional[int] = None,
             parallel_block: bool = False,
             remat_group: int = 1,
             weight_quant: str = "none",
             cache_quant: str = "none",
             capacity_factor: Optional[float] = None,
             microbatch_override: Optional[int] = None,
             extra_tag: str = "") -> Dict[str, Any]:
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config
    from repro.core.blocks_lm import build_block_table
    from repro.distributed.sharding import (params_shardings, plan_for,
                                            use_rules)
    from repro.launch.mesh import make_production_mesh
    from repro.models import kvcache as KC
    from repro.models.model_zoo import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import constant
    from repro.train.state import init_train_state, make_train_step

    t_start = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))

    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return {"cell": cell_id(arch, shape_name, mesh_kind),
                "status": "skipped(full-attention)",
                "note": "long_500k requires sub-quadratic attention "
                        "(DESIGN.md §Arch-applicability)"}

    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    if causal_skip:
        cfg = dataclasses.replace(cfg, attn_causal_skip=True)
    if parallel_block:
        cfg = dataclasses.replace(cfg, parallel_block=True)
    if remat_group > 1:
        cfg = dataclasses.replace(cfg, remat_group=remat_group)
    if weight_quant != "none":
        cfg = dataclasses.replace(cfg, weight_quant=weight_quant)
    if cache_quant != "none":
        cfg = dataclasses.replace(cfg, cache_quant=cache_quant)
    if capacity_factor and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor))

    mode = "train" if shape.kind == "train" else "serve"
    bytes_per_param = {"int8": 1.0, "int4": 0.5}.get(cfg.weight_quant, 2.0)
    # plan_for decides serve-FSDP from bf16 bytes; feed it the effective
    # byte count so quantized weights can stay TP-only (no per-token
    # weight gathers)
    plan = plan_for(mesh, arch, mode, shape_name,
                    int(cfg.param_count() * bytes_per_param / 2))
    model = build_model(cfg, plan)

    dp = int(np.prod([mesh.shape[a] for a in plan.dp_axes])) if plan.dp_axes else 1
    # effective devices doing distinct compute (roofline denominator):
    # whisper replicates over "model"; mamba2 long-context leaves "data" idle
    eff = dp * plan.tp_size
    if shape_name == "long_500k":
        data_sz = int(mesh.shape.get("data", 1))
        eff = plan.tp_size * (data_sz if cfg.family != "ssm" else 1)
    result: Dict[str, Any] = {
        "cell": cell_id(arch, shape_name, mesh_kind) + extra_tag,
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": n_dev, "kind": shape.kind,
        "tp": plan.tp_size,
        "dp": dp,
        "eff_devices": eff,
        "fsdp": plan.lookup("embed") is not None,
        "family": cfg.family,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "tokens": shape.tokens,
        "weight_quant": cfg.weight_quant,
        "cache_quant": cfg.cache_quant,
        "parallel_block": cfg.parallel_block,
        "remat_group": cfg.remat_group,
        "tp_ar_per_layer": 1 if cfg.parallel_block else 2,
        "grad_rs_bytes": 2.0 if cfg.param_dtype == "bfloat16" else 4.0,
        "bytes_per_param": bytes_per_param,
        "status": "running",
    }

    with mesh, use_rules(plan):
        if shape.kind == "train":
            mb = MICROBATCH.get(arch, 1)
            if multi_pod:
                mb = max(1, mb // 2)
            if cfg.remat_group > 1:
                mb = max(1, mb // cfg.remat_group)
            if microbatch_override:
                mb = microbatch_override
            result["microbatch"] = mb
            table = (build_block_table(model, shape) if instrument else None)
            opt_cfg = AdamWConfig()
            step_fn = make_train_step(model, opt_cfg, constant(1e-4),
                                      table=table, microbatch=mb,
                                      instrument=instrument)
            state_struct = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0),
                                         opt_cfg, table))
            p_axes = model.axes()
            p_shard = params_shardings(mesh, plan, p_axes)
            rep = NamedSharding(mesh, P())
            from repro.optim.adamw import OptState
            opt_shard = OptState(rep, p_shard, p_shard, p_shard)
            meter_shard = (jax.tree.map(lambda _: rep, state_struct.meter)
                           if state_struct.meter is not None else None)
            from repro.train.state import TrainState
            state_shard = TrainState(rep, p_shard, opt_shard, rep, meter_shard)
            batch_struct = model.input_specs(shape)
            bspec = {
                "tokens": NamedSharding(mesh, plan.spec(("batch", "seq"))),
                "labels": NamedSharding(mesh, plan.spec(("batch", "seq"))),
            }
            if "frames" in batch_struct:
                bspec["frames"] = NamedSharding(
                    mesh, plan.spec(("batch", None, None)))
            if "patches" in batch_struct:
                bspec["patches"] = NamedSharding(
                    mesh, plan.spec(("batch", None, None)))
            jfn = jax.jit(step_fn, in_shardings=(state_shard, bspec),
                          donate_argnums=(0,))
            lowered = jfn.lower(state_struct, batch_struct)
            state_bytes = _tree_bytes_per_device(state_struct, state_shard)
            result["state_bytes_per_device"] = state_bytes
            from repro.core.unit_of_work import trace_cost
            tc = trace_cost(step_fn, state_struct, batch_struct)
            result["trace_flops_global"] = tc.flops
            result["trace_bytes_global"] = tc.bytes
            result["trace_ops_global"] = tc.ops

        else:
            params_struct = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            p_shard = params_shardings(mesh, plan, model.axes())
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_spec = KC.cache_specs(cache_struct, plan)
            c_shard = jax.tree.map(
                lambda spec: NamedSharding(mesh, spec), c_spec,
                is_leaf=lambda x: isinstance(x, P))
            if shape.kind == "prefill":
                batch_struct = model.input_specs(shape)
                bspec = {"tokens": NamedSharding(mesh, plan.spec(("batch", "seq")))}
                if "frames" in batch_struct:
                    bspec["frames"] = NamedSharding(
                        mesh, plan.spec(("batch", None, None)))
                if "patches" in batch_struct:
                    bspec["patches"] = NamedSharding(
                        mesh, plan.spec(("batch", None, None)))
                jfn = jax.jit(model.prefill,
                              in_shardings=(p_shard, bspec, c_shard),
                              donate_argnums=(2,))
                lowered = jfn.lower(params_struct, batch_struct, cache_struct)
            else:
                tok_struct = model.input_specs(shape)["token"]
                tspec = NamedSharding(mesh, plan.spec(("batch", None)))
                jfn = jax.jit(model.decode_step,
                              in_shardings=(p_shard, tspec, c_shard),
                              donate_argnums=(2,))
                lowered = jfn.lower(params_struct, tok_struct, cache_struct)
            result["params_bytes_per_device"] = _tree_bytes_per_device(
                params_struct, p_shard)
            result["cache_bytes_per_device"] = _tree_bytes_per_device(
                cache_struct, c_shard)
            from repro.core.unit_of_work import trace_cost
            if shape.kind == "prefill":
                tc = trace_cost(model.prefill, params_struct, batch_struct,
                                cache_struct)
            else:
                tc = trace_cost(model.decode_step, params_struct, tok_struct,
                                cache_struct)
            result["trace_flops_global"] = tc.flops
            result["trace_bytes_global"] = tc.bytes
            result["trace_ops_global"] = tc.ops

        result["lower_s"] = time.time() - t_start
        t_c = time.time()
        compiled = lowered.compile()
        result["compile_s"] = time.time() - t_c

        ca = compiled.cost_analysis() or {}
        result["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "optimal_seconds",
             "bytes accessed output", "utilization operand 0 {}")}
        result["flops"] = float(ca.get("flops", 0.0))
        result["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))

        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes", "generated_code_size_in_bytes",
                             "alias_size_in_bytes"):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        result[f"mem_{attr}"] = int(v)
        except Exception as e:                        # pragma: no cover
            result["memory_analysis_error"] = str(e)

        from repro.core.hlo_analysis import collective_stats, op_histogram
        hlo = compiled.as_text()
        result["hlo_bytes"] = len(hlo)
        result["collectives"] = collective_stats(hlo)
        result["collective_bytes"] = sum(
            v["bytes"] for v in result["collectives"].values())
        hist = op_histogram(hlo)
        result["op_histogram_top"] = dict(
            sorted(hist.items(), key=lambda kv: -kv[1])[:20])

    result["status"] = "ok"
    result["total_s"] = time.time() - t_start
    return result


# ---------------------------------------------------------------------------


def all_cells():
    from repro.configs import SHAPES, get_config, list_archs
    for arch in list_archs():
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-instrument", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--remat")
    ap.add_argument("--attn-chunk", type=int)
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--parallel-block", action="store_true")
    ap.add_argument("--remat-group", type=int, default=1)
    ap.add_argument("--weight-quant", default="none")
    ap.add_argument("--cache-quant", default="none")
    ap.add_argument("--capacity-factor", type=float)
    ap.add_argument("--microbatch", type=int)
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape, mesh in all_cells():
            path = os.path.join(args.out, cell_id(arch, shape, mesh) + ".json")
            if args.skip_existing and os.path.exists(path):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", args.out]
            if args.no_instrument:
                cmd.append("--no-instrument")
            print(f"=== {cell_id(arch, shape, mesh)}", flush=True)
            rc = subprocess.call(cmd)
            if rc != 0:
                failures.append(cell_id(arch, shape, mesh))
        print("failures:", failures)
        return 1 if failures else 0

    assert args.arch and args.shape
    path = os.path.join(args.out,
                        cell_id(args.arch, args.shape, args.mesh)
                        + args.tag + ".json")
    try:
        res = run_cell(args.arch, args.shape, args.mesh,
                       instrument=not args.no_instrument,
                       remat=args.remat, attn_chunk=args.attn_chunk,
                       causal_skip=args.causal_skip,
                       parallel_block=args.parallel_block,
                       remat_group=args.remat_group,
                       weight_quant=args.weight_quant,
                       cache_quant=args.cache_quant,
                       capacity_factor=args.capacity_factor,
                       microbatch_override=args.microbatch,
                       extra_tag=args.tag)
    except Exception:
        res = {"cell": cell_id(args.arch, args.shape, args.mesh) + args.tag,
               "status": "error", "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    ok = res["status"].startswith(("ok", "skipped"))
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("op_histogram_top", "traceback")}, indent=1))
    if not ok:
        print(res.get("traceback", ""), file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
