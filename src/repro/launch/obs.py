"""Trace/metrics summarizer + merger for ``repro.obs`` run directories.

``python -m repro.launch.obs RUN_DIR`` finds every ``trace.jsonl`` /
``trace.json`` under the directory (a single file path works too), prints a
per-span aggregate table (count, total/mean/max ms) and, when a
``metrics.json`` snapshot is present, the metrics table.  With
``--merge-out PATH`` all discovered events are merged into one
Chrome-trace/Perfetto ``trace.json`` — the multi-process/multi-host story:
each worker streams its own JSONL sink, the merger joins them on one
timeline (tracks keyed by pid).

Examples:
    PYTHONPATH=src python -m repro.launch.pipeline ... --trace /tmp/run
    PYTHONPATH=src python -m repro.launch.obs /tmp/run
    PYTHONPATH=src python -m repro.launch.obs /tmp/run \
        --merge-out /tmp/run/merged.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from repro.obs import chrome_trace, read_events, span_summary

TRACE_NAMES = ("trace.jsonl", "trace.json")


def find_trace_files(root: str) -> List[str]:
    """Trace files under ``root`` (depth-first, stable order).  A
    ``trace.json`` next to a ``trace.jsonl`` is skipped — it is the export
    of the same events, and counting both would double every span."""
    if os.path.isfile(root):
        return [root]
    out: List[str] = []
    for d, _, files in sorted(os.walk(root)):
        present = [n for n in TRACE_NAMES if n in files]
        if "trace.jsonl" in present:
            out.append(os.path.join(d, "trace.jsonl"))
        elif present:
            out.append(os.path.join(d, present[0]))
    return out


def find_metrics_files(root: str) -> List[str]:
    if os.path.isfile(root):
        return []
    return [os.path.join(d, "metrics.json")
            for d, _, files in sorted(os.walk(root))
            if "metrics.json" in files]


def summary_table(rows: List[Dict]) -> str:
    if not rows:
        return "(no spans)"
    w = max(len(r["name"]) for r in rows)
    lines = [f"{'span'.ljust(w)}  {'count':>6}  {'total_ms':>10}  "
             f"{'mean_ms':>10}  {'max_ms':>10}"]
    for r in rows:
        lines.append(f"{r['name'].ljust(w)}  {r['count']:>6}  "
                     f"{r['total_ms']:>10.2f}  {r['mean_ms']:>10.2f}  "
                     f"{r['max_ms']:>10.2f}")
    return "\n".join(lines)


def metrics_table(snapshots: Dict[str, Dict]) -> str:
    """Render merged metrics snapshots (counters summed across files,
    gauges/histograms reported per file when they collide)."""
    lines = []
    for path, snap in snapshots.items():
        lines.append(f"# {path}")
        w = max((len(n) for n in snap), default=6)
        for name, s in sorted(snap.items()):
            if s["type"] == "histogram":
                val = (f"count={s.get('count', 0)}"
                       + (f" mean={s['mean']:.6g} p95={s['p95']:.6g}"
                          if s.get("count") else ""))
            else:
                val = f"{s['value']:.6g}"
            lines.append(f"  {name.ljust(w)}  {s['type']:<9}  {val}")
    return "\n".join(lines) if lines else "(no metrics snapshots)"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize/merge repro.obs traces from a run directory")
    ap.add_argument("run_dir", help="run directory (or a single trace file)")
    ap.add_argument("--merge-out", metavar="PATH",
                    help="write all events as one Chrome-trace JSON file")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of tables")
    args = ap.parse_args(argv)

    files = find_trace_files(args.run_dir)
    if not files and not args.merge_out:
        print(f"no trace files under {args.run_dir}", file=sys.stderr)
        return 1
    events = []
    for path in files:
        events.extend(read_events(path))
    spans = span_summary(events)

    if args.merge_out:
        doc = chrome_trace(events)
        with open(args.merge_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# merged {len(events)} events from {len(files)} file(s) "
              f"-> {args.merge_out}")

    snapshots = {}
    for mp in find_metrics_files(args.run_dir):
        with open(mp) as f:
            snapshots[mp] = json.load(f)

    if args.json:
        print(json.dumps({"files": files, "events": len(events),
                          "spans": spans, "metrics": snapshots}, indent=1))
        return 0
    print(f"# {len(events)} events from {len(files)} trace file(s)")
    print(summary_table(spans))
    if snapshots:
        print()
        print(metrics_table(snapshots))
    return 0


if __name__ == "__main__":
    sys.exit(main())
