"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Method (documented in EXPERIMENTS.md; motivated by two verified CPU-backend
facts — ``cost_analysis`` is per-partition and counts scan bodies ONCE):

- **compute term**: exact executed FLOPs from the scan-aware jaxpr walker
  (the same unit-of-work machinery the paper contribution uses), divided by
  the cell's *effective* devices (replicated-compute archs don't get credit
  for idle axes), over 197 TFLOP/s bf16.
- **memory term**: analytic minimal HBM traffic per device per step
  (weights×microbatch passes, optimizer read+write, activation stash
  save+restore under remat, KV-cache traffic, logits) over 819 GB/s.
- **collective term**: analytic per-device collective bytes from the sharding
  plan (TP all-reduces per layer fwd+bwd, FSDP all-gathers per microbatch,
  gradient reduce-scatter, pod-axis gradient all-reduce), cross-checked
  against the HLO-parsed per-iteration collective set, over 50 GB/s ICI.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

V5E_FLOPS = 197e12
V5E_HBM = 819e9
V5E_ICI_LINK = 50e9          # per link
V5E_ICI_AXIS = 2 * V5E_ICI_LINK   # 2 links per torus dimension (ring)
V5E_DCI = 50e9               # pod-to-pod (conservative: one link-equivalent)


def model_flops(cell: Dict[str, Any]) -> float:
    n = cell["active_param_count"]
    t = cell["tokens"]
    if cell["kind"] == "train":
        return 6.0 * n * t
    return 2.0 * n * t


def analytic_hbm_bytes(cell: Dict[str, Any]) -> float:
    """Per-device minimal HBM traffic per step (bytes)."""
    tp = max(cell.get("tp", 1), 1)
    dp = max(cell.get("dp", 1), 1)
    L = cell["n_layers"]
    d = cell["d_model"]
    kind = cell["kind"]
    mb = cell.get("microbatch", 1)
    n_params = cell["param_count"]
    bpp = cell.get("bytes_per_param", 2.0)
    p_c = bpp * n_params / tp               # compute-visible weights/dev

    if kind == "train":
        tokens_dev = cell["tokens"] / dp
        t_mb = tokens_dev / mb
        weights = 3.0 * p_c * mb             # fwd read + bwd read + grad write
        opt = 2.0 * 12.0 * n_params / (tp * dp)   # m,v,master read+write f32
        stash = 2.0 * tokens_dev * d * 2.0 * L    # save+restore layer inputs
        logits = 0.0                              # fused into loss (z-loss fwd)
        return weights + opt + stash + logits
    if kind == "prefill":
        tokens_dev = cell["tokens"] / dp
        act = 2.0 * tokens_dev * d * 2.0 * L
        cache = cell.get("cache_bytes_per_device", 0.0)
        return p_c + act + cache
    # decode: weights + cache read dominate
    cache = cell.get("cache_bytes_per_device", 0.0)
    return p_c + cache


def _tp_ar_per_layer(cell: Dict[str, Any]) -> float:
    """Forward TP all-reduces per layer, by family:
    dense/moe/vlm/encdec: 2 (attention out-proj + mlp/moe out) — 1 with
    parallel blocks (all-reduce reassociation); ssm: 1 (in_proj is
    column-parallel, only out_proj contracts a sharded dim); hybrid
    (zamba2): 1 per mamba layer + 2 per shared-attn application
    (every 6 layers) ≈ 1.33."""
    if cell.get("parallel_block"):
        return 1.0
    fam = cell.get("family", "dense")
    if fam == "ssm":
        return 1.0
    if fam == "hybrid":
        return 1.0 + 2.0 / 6.0
    return 2.0


def analytic_collective_bytes(cell: Dict[str, Any]) -> Dict[str, float]:
    """Per-device collective payload per step, split by fabric:
    {"ici": bytes over intra-pod torus axes, "pod": bytes over the pod axis}.
    """
    tp = max(cell.get("tp", 1), 1)
    dp = max(cell.get("dp", 1), 1)
    L = cell["n_layers"]
    d = cell["d_model"]
    kind = cell["kind"]
    mb = cell.get("microbatch", 1)
    n_params = cell["param_count"]
    bpp = cell.get("bytes_per_param", 2.0)
    p_c = bpp * n_params / tp
    multi_pod = cell.get("mesh") == "multi"
    grad_rs_bytes = cell.get("grad_rs_bytes", 4.0)   # f32 RS (perf lever: 2.0)
    tp_ar_per_layer = _tp_ar_per_layer(cell)          # fwd ARs per layer

    ici = 0.0
    pod = 0.0
    if kind == "train":
        tokens_dev = cell["tokens"] / dp
        if tp > 1:
            # tp_ar_per_layer fwd + same again bwd, [t_mb, d] bf16 payloads;
            # ring all-reduce moves 2(tp-1)/tp of the payload.
            ar_payload = (tokens_dev / mb) * d * 2.0
            ici += (2 * tp_ar_per_layer) * L * mb * ar_payload \
                * 2.0 * (tp - 1) / tp
        if cell.get("fsdp"):
            ici += 2.0 * p_c * mb * (dp - 1) / dp          # re-gather fwd+bwd
            ici += grad_rs_bytes * n_params / tp * (dp - 1) / dp   # grad RS
        if multi_pod:
            pod += 2.0 * grad_rs_bytes * n_params / (tp * dp)      # pod grad AR
        return {"ici": ici, "pod": pod}
    if kind == "prefill":
        tokens_dev = cell["tokens"] / dp
        if tp > 1:
            ici += tp_ar_per_layer * L * tokens_dev * d * 2.0 \
                * 2.0 * (tp - 1) / tp
        if cell.get("fsdp"):
            ici += p_c * (dp - 1) / dp
        return {"ici": ici, "pod": pod}
    # decode
    b_dev = cell["tokens"] / dp
    if tp > 1:
        ici += tp_ar_per_layer * L * b_dev * d * 2.0 * 2.0 * (tp - 1) / tp
    if cell.get("fsdp"):
        ici += p_c * (dp - 1) / dp
    return {"ici": ici, "pod": pod}


LEVERS = {
    "compute": ("raise per-device arithmetic efficiency: causal-skip "
                "attention schedule, drop remat recompute (selective "
                "policy), or reduce head/vocab padding waste"),
    "memory": ("cut HBM traffic: larger microbatch (fewer weight passes), "
               "selective remat (smaller stash), bf16 optimizer reads, or "
               "fuse logits into the loss"),
    "collective": ("cut ICI bytes: fewer/coarser TP all-reduces (merge "
                   "attn+mlp), int8 gradient compression, keep FSDP "
                   "gathers off the pod axis, overlap with compute via "
                   "latency-hiding scheduler"),
}


def analyze_cell(cell: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if cell.get("status") != "ok":
        return None
    eff = max(cell.get("eff_devices", cell["devices"]), 1)
    tf = cell.get("trace_flops_global", 0.0)
    compute_s = tf / eff / V5E_FLOPS
    hbm = analytic_hbm_bytes(cell)
    memory_s = hbm / V5E_HBM
    coll_parts = analytic_collective_bytes(cell)
    coll = coll_parts["ici"] + coll_parts["pod"]
    collective_s = coll_parts["ici"] / V5E_ICI_AXIS \
        + coll_parts["pod"] / V5E_DCI
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell)
    bound = max(terms.values())
    roofline_frac = compute_s / bound if bound > 0 else 0.0
    return {
        "cell": cell["cell"],
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": tf,
        "useful_ratio": mf / tf if tf else 0.0,
        "roofline_fraction": roofline_frac,
        "hbm_bytes_dev": hbm,
        "collective_bytes_dev": coll,
        "hlo_collective_bytes_periter": cell.get("collective_bytes", 0.0),
        "lever": LEVERS[dominant],
    }


def load_cells(dirpath: str) -> List[Dict[str, Any]]:
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def markdown_table(rows: List[Dict[str, Any]], skipped: List[Dict]) -> str:
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    for s in skipped:
        lines.append(f"| {s['cell']} | — | — | — | "
                     f"{s['status']} | — | — |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    rows, skipped = [], []
    for c in cells:
        if c.get("status", "").startswith("skipped"):
            skipped.append(c)
            continue
        r = analyze_cell(c)
        if r:
            rows.append(r)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows, skipped))
    for r in rows:
        print(f"{r['cell']}: dominant={r['dominant']}; lever: {r['lever']}")


if __name__ == "__main__":
    main()
