"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; call it only after the launcher has configured
``XLA_FLAGS`` (dryrun.py) or on real hardware.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many (host) devices exist — tests/benches."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
