"""GPipe-style pipeline parallelism over a "stage" mesh axis.

The production meshes in this assignment are (data, model)-shaped, so PP is
an *optional* extra dimension for deployments that prefer pipelining over
FSDP for very deep models (88-layer mistral at low batch). Implementation:
shard_map over the stage axis; each device owns one stage's stacked params;
a lax.scan over M + S - 1 ticks streams microbatches through a
collective-permute ring (the classic GPipe schedule, bubble fraction
(S-1)/(M+S-1)).

This is deliberately jax-native (shard_map + ppermute, no NCCL-style
emulation) per the brief's hardware-adaptation guidance.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                        # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:                      # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def gpipe(stage_fn: Callable[[Any, jax.Array], jax.Array],
          mesh: Mesh, axis: str = "stage"):
    """Build a pipelined apply: (stage_params_stacked [S, ...],
    microbatches [M, mb, ...]) -> outputs [M, mb, ...].

    ``stage_fn(params_one_stage, x) -> y`` must be shape-preserving
    (x and y share shape/dtype — standard residual-stack stages).
    """
    S = int(mesh.shape[axis])

    def body(params_local, xs):
        # params_local: [1, ...] (this device's stage); xs: [M, mb, ...]
        p = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        M = xs.shape[0]
        total = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (while available); other stages
            # consume what the previous stage permuted in
            feed = xs[jnp.clip(t, 0, M - 1)]
            inp = jnp.where(idx == 0, feed, buf)
            y = stage_fn(p, inp)
            buf_next = jax.lax.ppermute(y, axis, perm)
            mb = t - (S - 1)
            take = jnp.clip(mb, 0, M - 1)
            upd = jnp.where((idx == S - 1) & (mb >= 0), y, outs[take])
            outs = outs.at[take].set(upd)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        # the carry becomes device-varying over the stage axis inside the
        # loop; mark the initial values accordingly (shard_map VMA typing)
        try:
            buf0 = jax.lax.pcast(buf0, (axis,), to="varying")
            outs0 = jax.lax.pcast(outs0, (axis,), to="varying")
        except (AttributeError, TypeError):      # older jax: no VMA tracking
            pass
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(total))
        # replicate the last stage's outputs to every stage
        mask = (idx == S - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
