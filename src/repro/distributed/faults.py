"""Failure-injection / heartbeat coordination harness (single-process
simulation of the multi-worker control plane; the same state machine runs
per-host against a distributed KV store in production).

Models the fleet behaviors the framework must survive at 1000+ nodes:
- missed heartbeats -> worker declared dead -> run restarts from the last
  committed checkpoint (tested in tests/test_fault_tolerance.py),
- straggling workers -> logged + (optionally) excluded at the next elastic
  rescale,
- elastic rescale -> new mesh, checkpoint resharded on restore.

Shares the framework failure vocabulary (``repro.faults``) with the
pipeline scheduler/store: event records come from ``fault_event`` and a
step function that dies with :class:`~repro.faults.WorkerKilled` (e.g.
raised by a :class:`~repro.faults.FaultInjector` ``kill`` rule) triggers
the same restart-from-checkpoint path as a scheduled kill point — the
heartbeat/restart state machine and the artifact pipeline speak one
failure language.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.faults import WorkerKilled, fault_event


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    step: int = 0
    alive: bool = True
    slow_strikes: int = 0


class HeartbeatCoordinator:
    def __init__(self, n_workers: int, *, timeout_s: float = 1.0,
                 straggler_factor: float = 3.0):
        self.timeout = timeout_s
        self.straggler_factor = straggler_factor
        now = time.monotonic()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i, now) for i in range(n_workers)}
        self.events: List[Dict] = []
        # per-instance step-time window: straggler medians must never
        # leak between coordinators (or between tests)
        self._times: List[float] = []
        self._lock = threading.Lock()

    def heartbeat(self, worker_id: int, step: int,
                  step_time_s: Optional[float] = None) -> None:
        with self._lock:
            w = self.workers[worker_id]
            w.last_heartbeat = time.monotonic()
            w.step = step
            if step_time_s is not None:
                med = self._median_step_time(step_time_s)
                if step_time_s > self.straggler_factor * med:
                    w.slow_strikes += 1
                    self.events.append(fault_event(
                        "straggler", worker=worker_id, step=step,
                        t=step_time_s))

    def _median_step_time(self, t: float) -> float:
        self._times.append(t)
        s = sorted(self._times[-100:])
        return s[len(s) // 2]

    def check(self) -> List[int]:
        """Returns newly-dead worker ids (missed heartbeat past timeout)."""
        now = time.monotonic()
        dead = []
        with self._lock:
            for w in self.workers.values():
                if w.alive and now - w.last_heartbeat > self.timeout:
                    w.alive = False
                    dead.append(w.worker_id)
                    self.events.append(fault_event(
                        "dead", worker=w.worker_id, step=w.step))
        return dead

    def alive_count(self) -> int:
        with self._lock:
            return sum(1 for w in self.workers.values() if w.alive)

    def min_committed_step(self) -> int:
        with self._lock:
            alive = [w.step for w in self.workers.values() if w.alive]
        return min(alive) if alive else 0


class FaultInjectingRun:
    """Drives a step function across simulated workers, killing some at
    scheduled steps; on death the run restarts every worker from the last
    checkpoint — asserts end-state equivalence with an uninterrupted run."""

    def __init__(self, n_workers: int, run_steps: Callable[[int, int], int],
                 *, ckpt_every: int, kill_at: Dict[int, int]):
        # run_steps(from_step, to_step) -> last completed step, raises on kill
        self.n_workers = n_workers
        self.run_steps = run_steps
        self.ckpt_every = ckpt_every
        self.kill_at = dict(kill_at)
        self.restarts = 0
        self.events: List[Dict] = []

    def run(self, total_steps: int) -> int:
        step = 0
        while step < total_steps:
            kill_points = sorted(s for s in self.kill_at.values()
                                 if s > step)
            target = min([total_steps] + kill_points)
            killed = False
            try:
                step = self.run_steps(step, target)
            except WorkerKilled as e:
                # a step function sharing the pipeline failure vocabulary
                # (e.g. a FaultInjector kill rule) died mid-range: same
                # restart-from-checkpoint path as a scheduled kill point
                killed = True
                self.events.append(fault_event("worker_killed", step=step,
                                               detail=str(e)))
            if step < total_steps and (
                    killed or (kill_points and step >= kill_points[0] - 1)):
                # simulate crash: roll back to last committed checkpoint
                self.restarts += 1
                step = (step // self.ckpt_every) * self.ckpt_every
                self.kill_at = {w: s for w, s in self.kill_at.items()
                                if s > target}
        return step
