from repro.distributed.sharding import (  # noqa: F401
    ShardingPlan, logical_rules, shard, spec_for, set_rules, active_rules,
    plan_for, params_shardings,
)
from repro.distributed.pipeline import bubble_fraction, gpipe  # noqa: F401
from repro.distributed.faults import (  # noqa: F401
    FaultInjectingRun, HeartbeatCoordinator,
)
