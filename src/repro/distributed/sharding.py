"""Logical-axis → mesh-axis sharding rules.

Model code annotates params and activations with *logical* axis names; the
active :class:`ShardingPlan` maps those to mesh axes.  Rules differ between
training (2D FSDP×TP) and serving (TP + batch- or sequence-sharded KV), and
per-arch overrides can disable tensor parallelism for tiny models (whisper).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Resolved mapping from logical axes to mesh axes."""
    rules: Tuple[Tuple[str, Any], ...]     # logical -> mesh axis (or tuple / None)
    tp_size: int                           # size of the tensor axis (1 = TP off)
    dp_axes: Tuple[str, ...]               # batch/FSDP mesh axes
    tp_axis: Optional[str]                 # tensor mesh axis name

    def lookup(self, logical: Optional[str]):
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        resolved, used = [], set()
        for a in axes:
            v = self.lookup(a)
            # a mesh axis may appear at most once in a PartitionSpec
            flat = v if isinstance(v, tuple) else ((v,) if v else ())
            if any(m in used for m in flat):
                v = None
            else:
                used.update(flat)
            resolved.append(v)
        return P(*resolved)


def _mk(rules: Dict[str, Any], tp_size: int, dp_axes, tp_axis) -> ShardingPlan:
    return ShardingPlan(tuple(rules.items()), tp_size, tuple(dp_axes), tp_axis)


def logical_rules(mesh: Mesh, *, mode: str = "train",
                  tp_enabled: bool = True,
                  shard_seq: bool = False) -> ShardingPlan:
    """Build the sharding plan for a mesh.

    mode="train":  batch over (pod?,data); params 2D: FSDP("data") × TP("model").
    mode="serve":  params TP only (replicated over data); batch over (pod?,data)
                   unless ``shard_seq`` (long-context) — then KV seq over "data".
    """
    names = mesh.axis_names
    pod = "pod" if "pod" in names else None
    data = "data" if "data" in names else None
    model = "model" if "model" in names else None
    if not tp_enabled:
        model = None
    batch_axes = tuple(a for a in (pod, data) if a)
    if shard_seq:
        # long-context decode: batch=1 — the "data" axis shards the KV
        # sequence instead of the batch
        batch_axes = ()
    batch = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    fsdp = data if mode in ("train", "serve_fsdp") and not shard_seq else None
    tp_size = int(mesh.shape["model"]) if (model and "model" in names) else 1

    rules: Dict[str, Any] = {
        "batch": batch,
        "embed": fsdp,
        "mlp": model,
        "heads": model,
        "kv_heads": model,
        "head_dim": None,
        "vocab": model,
        "layer": None,
        "experts": model,
        "expert_mlp": None,
        "ssm_inner": model,
        "ssm_state": None,
        "conv": None,
        "act_embed": None,        # activation d_model dim
        "act_heads": model,       # activation head dim
        "act_vocab": model,       # logits vocab dim
        "kv_seq": ("data" if (shard_seq and data) else None),
        "seq": None,
    }
    return _mk(rules, tp_size, batch_axes, model)


# --------------------------------------------------------------------------
# Active-plan context: model code calls shard(x, *logical_axes); it is a
# no-op unless a plan is active (tests / single-device runs).
# --------------------------------------------------------------------------

_STATE = threading.local()


def set_rules(plan: Optional[ShardingPlan]):
    _STATE.plan = plan


def active_rules() -> Optional[ShardingPlan]:
    return getattr(_STATE, "plan", None)


@contextlib.contextmanager
def use_rules(plan: Optional[ShardingPlan]):
    prev = active_rules()
    set_rules(plan)
    try:
        yield
    finally:
        set_rules(prev)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    plan = active_rules()
    if plan is None:
        return x
    spec = plan.spec(axes)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def spec_for(axes: Sequence[Optional[str]]) -> P:
    plan = active_rules()
    if plan is None:
        return P()
    return plan.spec(axes)


def plan_for(mesh: Mesh, arch_name: str, mode: str, shape_name: str = "",
             param_count: int = 0) -> ShardingPlan:
    """Per-arch overrides:

    - tiny models (whisper) skip TP entirely — replicating a 39 M-param model
      beats paying collectives for 24-wide matmuls;
    - long_500k shards the KV sequence over "data" (batch=1);
    - big-arch serving turns on FSDP-style weight sharding over "data" when
      bf16 params / tp_size would exceed ~half of v5e HBM (mistral-123B,
      internvl-76B, llama4-scout served on 256 chips need 2D weight sharding).
    """
    tp_enabled = arch_name not in ("whisper-tiny",)
    shard_seq = shape_name == "long_500k"
    tp = int(mesh.shape.get("model", 1)) if tp_enabled else 1
    if mode == "serve" and param_count * 2 / max(tp, 1) > 8e9:
        mode = "serve_fsdp"
    return logical_rules(mesh, mode=mode, tp_enabled=tp_enabled,
                         shard_seq=shard_seq)


def params_shardings(mesh: Mesh, plan: ShardingPlan, axes_tree) -> Any:
    """Map an axes pytree (tuples of logical names) to NamedShardings."""
    def _one(axes):
        return NamedSharding(mesh, plan.spec(axes))
    return jax.tree.map(_one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
