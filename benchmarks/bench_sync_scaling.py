"""Paper Fig. 4: hook-synchronization overhead vs parallelism.

Threads -> data-parallel shards: the WorkMeter's dynamic counters need a
cross-shard psum, so hook cost grows with the DP degree.  Each shard count
runs in a subprocess (XLA locks the host device count at first init)."""
from __future__ import annotations

import json
import subprocess
import sys
from typing import List

from benchmarks.common import Row

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
shard_map = jax.shard_map

n = %d
mesh = Mesh(np.array(jax.devices()).reshape(n), ("dp",))
D = 256
def work(x):
    for _ in range(8):
        x = jnp.tanh(x @ x)
    return x

def step_plain(x):
    return shard_map(lambda v: work(v), mesh=mesh, in_specs=P("dp"),
                     out_specs=P("dp"))(x)

def step_hooked(x, counts):
    def f(v, c):
        v = work(v)
        c = c + jnp.ones((16,), jnp.int32)          # hook counters
        c = jax.lax.psum(c, "dp")                    # synchronization
        return v, c
    return shard_map(f, mesh=mesh, in_specs=(P("dp"), P()),
                     out_specs=(P("dp"), P()))(x, counts)

x = jnp.ones((n * 4, D, D)) * 0.01
c = jnp.zeros((16,), jnp.int32)
r = step_plain(x); jax.block_until_ready(r)
r, c2 = step_hooked(x, c); jax.block_until_ready(r)

def t(fn, reps=10):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out[0] if isinstance(out, tuple) else out)
    return (time.perf_counter() - t0) / reps

tp = t(lambda: step_plain(x))
th = t(lambda: step_hooked(x, c))
print(json.dumps({"plain_us": tp * 1e6, "hooked_us": th * 1e6}))
"""


def run() -> List[Row]:
    rows: List[Row] = []
    for n in (1, 2, 4, 8):
        out = subprocess.run(
            [sys.executable, "-c", _CHILD % (n, n)],
            capture_output=True, text=True, cwd=".")
        try:
            d = json.loads(out.stdout.strip().splitlines()[-1])
        except Exception:
            rows.append((f"sync_scaling/shards={n}", 0.0,
                         f"error:{out.stderr[-120:]}"))
            continue
        ratio = d["hooked_us"] / d["plain_us"]
        rows.append((f"sync_scaling/shards={n}", d["hooked_us"],
                     f"hook_sync_overhead={ratio:.3f}x"))
    return rows
