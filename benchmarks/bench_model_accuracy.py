"""Paper Fig. 11 / §V-B: nuggets as organic microbenchmarks to localize where
the backend's view diverges from the portable-IR view ("microcoding").

Per nugget-sized program we compare the portable jaxpr op histogram against
the compiled-HLO op histogram and report the largest deltas — on gem5 this
localized the paired-memory-op microcoding bug; here it localizes XLA
fusion/lowering decisions (e.g. N jaxpr ops -> 1 fusion; dot -> cublas-like
custom calls), which is exactly what a model-accuracy debugging session
inspects first."""
from __future__ import annotations

import collections
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.configs import get_config, reduced
from repro.core.hlo_analysis import histogram_delta, op_histogram
from repro.core.unit_of_work import _as_jaxpr, _sub_jaxprs
from repro.models.model_zoo import build_model


def jaxpr_histogram(jaxpr, mult: float = 1.0) -> collections.Counter:
    jaxpr = _as_jaxpr(jaxpr)
    hist: collections.Counter = collections.Counter()
    for eqn in jaxpr.eqns:
        subs, _ = _sub_jaxprs(eqn)
        if subs:
            for sj, m in subs:
                hist.update({k: v * m * mult
                             for k, v in jaxpr_histogram(sj).items()})
            hist[eqn.primitive.name] += mult
        else:
            hist[eqn.primitive.name] += mult
    return hist


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ("qwen3-1.7b", "mamba2-780m"):
        cfg = reduced(get_config(arch))
        m = build_model(cfg)
        params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        toks = jax.ShapeDtypeStruct((2, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}

        def nugget_fn(p, b):
            return m.loss(p, b)[0]

        jaxpr = jax.make_jaxpr(nugget_fn)(params, batch)
        jh = jaxpr_histogram(jaxpr)
        compiled = jax.jit(nugget_fn).lower(params, batch).compile()
        hh = op_histogram(compiled.as_text())

        total_ir = sum(jh.values())
        total_hlo = sum(hh.values())
        rows.append((f"model_accuracy/{arch}/ir_ops", total_ir,
                     f"hlo_ops={total_hlo};"
                     f"fusion_ratio={total_ir / max(total_hlo, 1):.2f}"))
        deltas = histogram_delta(
            {k: int(v) for k, v in jh.items()},
            {k: int(v) for k, v in hh.items()})
        for op, a, b in deltas[:5]:
            rows.append((f"model_accuracy/{arch}/delta/{op}", abs(a - b),
                         f"ir={a};hlo={b}"))
    return rows
