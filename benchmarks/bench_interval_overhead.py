"""Paper Fig. 2/3: interval-analysis overhead — Nugget hooks vs uninstrumented
execution vs a functional-simulation stand-in (op-by-op interpreted execution
via jax.disable_jit, the gem5-ATOMIC analogue on this host).

Reproduces the paper's ordering: hook overhead is a few percent; interpreted
("functional simulation") execution is orders of magnitude slower.

Also benchmarks the host-side analysis pipeline itself: legacy per-step
IntervalBuilder replay vs the vectorized batch path vs the chunked parallel
path vs a profile-cache hit, reporting steps/s and intervals/s.  Run
standalone (no model work, no jax) with::

    PYTHONPATH=src python -m benchmarks.bench_interval_overhead --smoke

which exits non-zero if the batch path fails to beat the legacy path or the
two disagree on the resulting profile.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Row, time_fn

ARCHS = ["qwen3-1.7b", "olmoe-1b-7b", "mamba2-780m", "zamba2-1.2b"]

# synthetic analysis workload: fine-grained hook stream (small per-step
# program, many steps) — the regime the paper's profiler runs in
ANALYSIS_N_BLOCKS = 48
ANALYSIS_N_STEPS = 2000
ANALYSIS_INTERVAL_STEPS = 2.5


def _analysis_workload(n_steps: int = ANALYSIS_N_STEPS):
    from repro.core.intervals_vec import as_steps
    from repro.core.registry import BlockDef, BlockTable, Segment

    rng = np.random.default_rng(0)
    blocks = [BlockDef(f"b{i}", cost_ops=float(rng.integers(1, 40)))
              for i in range(ANALYSIS_N_BLOCKS)]
    segs = [Segment(tuple(int(x) for x in
                          rng.integers(0, ANALYSIS_N_BLOCKS, 4)), 2)
            for _ in range(3)]
    table = BlockTable(blocks, segs)
    steps = as_steps(n_steps=n_steps)
    return table, steps, table.step_uow() * ANALYSIS_INTERVAL_STEPS


def _profiles_equal(p, q) -> bool:
    if p.n_intervals != q.n_intervals:
        return False
    return all(a.end_marker == b.end_marker and np.array_equal(a.bbv, b.bbv)
               and np.array_equal(a.stamps, b.stamps)
               for a, b in zip(p.intervals, q.intervals))


def run_analysis_throughput(n_steps: int = ANALYSIS_N_STEPS
                            ) -> Tuple[List[Row], bool]:
    """Legacy vs batch vs parallel vs cached analysis throughput.

    Returns (rows, ok): ok is False if the batch path is slower than the
    legacy path or produces a different profile.
    """
    from repro.core.intervals import build_profile
    from repro.core.profile_store import cached_build

    table, steps, iu = _analysis_workload(n_steps)
    rows: List[Row] = []
    times = {}
    profs = {}
    for method in ("legacy", "batch", "parallel"):
        times[method] = time_fn(
            lambda m=method: profs.__setitem__(
                m, build_profile(table, iu, steps, method=m)),
            repeats=3, warmup=1)
    with tempfile.TemporaryDirectory() as cache:
        cached_build(cache, table, iu, steps)                 # populate
        times["cached"] = time_fn(
            lambda: cached_build(cache, table, iu, steps), repeats=3,
            warmup=1)
    n_ivl = profs["legacy"].n_intervals
    for method in ("legacy", "batch", "parallel", "cached"):
        t = times[method]
        speed = times["legacy"] / t
        rows.append((f"interval_analysis/{method}", t * 1e6,
                     f"steps/s={n_steps / t:.0f} "
                     f"intervals/s={n_ivl / t:.0f} "
                     f"speedup={speed:.2f}x"))
    ok = (times["batch"] < times["legacy"]
          and _profiles_equal(profs["legacy"], profs["batch"])
          and _profiles_equal(profs["legacy"], profs["parallel"]))
    return rows, ok


def run() -> List[Row]:
    import jax

    from repro.configs import get_config, reduced
    from repro.train import Trainer

    def _step_time(tr: Trainer, instrumented: bool, steps: int = 4) -> float:
        state = tr.init_state()
        fn = tr._step_fn if instrumented else tr._uninstrumented
        batch = tr._device_batch(0)
        state, m, _ = fn(state, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for s in range(steps):
            state, m, _ = fn(state, tr._device_batch(s))
        jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / steps

    rows: List[Row] = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        tr = Trainer(cfg, seq_len=32, batch=4, instrument=True, donate=False)
        t_plain = _step_time(tr, False)
        t_hook = _step_time(tr, True)
        # functional-simulation stand-in: interpreted, op-by-op
        state = tr.init_state()
        batch = tr._device_batch(0)
        import repro.train.state as TS
        from repro.optim.schedule import constant
        raw_step = TS.make_train_step(tr.model, tr.opt_cfg,
                                      constant(1e-4), instrument=False)

        def interp():
            with jax.disable_jit():
                s2, m, _ = raw_step(state, batch)
                jax.block_until_ready(m["loss"])
        t_interp = time_fn(interp, repeats=1, warmup=0)
        rows.append((f"interval_overhead/{arch}/uninstrumented",
                     t_plain * 1e6, "baseline"))
        rows.append((f"interval_overhead/{arch}/nugget_hooks",
                     t_hook * 1e6,
                     f"slowdown={t_hook / t_plain:.3f}x"))
        rows.append((f"interval_overhead/{arch}/functional_sim",
                     t_interp * 1e6,
                     f"slowdown={t_interp / t_plain:.1f}x"))
    rows.extend(run_analysis_throughput()[0])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="analysis-throughput section only (no jax model "
                         "work); exit 1 if the batch path is slower than "
                         "legacy or not equivalent")
    ap.add_argument("--steps", type=int, default=ANALYSIS_N_STEPS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        rows, ok = run_analysis_throughput(args.steps)
    else:
        rows, ok = run(), True
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    if not ok:
        print("FAIL: batch path slower than legacy or not equivalent",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
