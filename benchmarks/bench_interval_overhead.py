"""Paper Fig. 2/3: interval-analysis overhead — Nugget hooks vs uninstrumented
execution vs a functional-simulation stand-in (op-by-op interpreted execution
via jax.disable_jit, the gem5-ATOMIC analogue on this host).

Reproduces the paper's ordering: hook overhead is a few percent; interpreted
("functional simulation") execution is orders of magnitude slower.
"""
from __future__ import annotations

import time
from typing import List

import jax

from benchmarks.common import Row, time_fn
from repro.configs import get_config, reduced
from repro.train import Trainer

ARCHS = ["qwen3-1.7b", "olmoe-1b-7b", "mamba2-780m", "zamba2-1.2b"]


def _step_time(tr: Trainer, instrumented: bool, steps: int = 4) -> float:
    state = tr.init_state()
    fn = tr._step_fn if instrumented else tr._uninstrumented
    batch = tr._device_batch(0)
    state, m, _ = fn(state, batch)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for s in range(steps):
        state, m, _ = fn(state, tr._device_batch(s))
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / steps


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        tr = Trainer(cfg, seq_len=32, batch=4, instrument=True, donate=False)
        t_plain = _step_time(tr, False)
        t_hook = _step_time(tr, True)
        # functional-simulation stand-in: interpreted, op-by-op
        state = tr.init_state()
        batch = tr._device_batch(0)
        import repro.train.state as TS
        from repro.optim.schedule import constant
        raw_step = TS.make_train_step(tr.model, tr.opt_cfg,
                                      constant(1e-4), instrument=False)

        def interp():
            with jax.disable_jit():
                s2, m, _ = raw_step(state, batch)
                jax.block_until_ready(m["loss"])
        t_interp = time_fn(interp, repeats=1, warmup=0)
        rows.append((f"interval_overhead/{arch}/uninstrumented",
                     t_plain * 1e6, "baseline"))
        rows.append((f"interval_overhead/{arch}/nugget_hooks",
                     t_hook * 1e6,
                     f"slowdown={t_hook / t_plain:.3f}x"))
        rows.append((f"interval_overhead/{arch}/functional_sim",
                     t_interp * 1e6,
                     f"slowdown={t_interp / t_plain:.1f}x"))
    return rows
