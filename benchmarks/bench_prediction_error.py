"""Paper Fig. 5: full-runtime prediction error by platform × method.

Random vs K-means nuggets, validated natively on two 'platforms' (f32 vs
bf16 compute — the container's stand-ins for distinct machines), without any
simulation.  Driven by the artifact pipeline: both methods share one store,
so the profile and full-run baselines are computed once per arch and the
K-means pass re-runs only select/mark/replay/validate."""
from __future__ import annotations

import tempfile
from typing import List

from benchmarks.common import Row
from repro.pipeline import Pipeline, PipelineConfig

ARCHS = ["olmoe-1b-7b", "qwen3-1.7b"]
N_STEPS = 28

METHODS = (("random", {"n_samples": 8, "seed": 0}),
           ("kmeans", {"seed": 0}))


def run() -> List[Row]:
    rows: List[Row] = []
    with tempfile.TemporaryDirectory(prefix="bench-pred-") as store:
        for arch in ARCHS:
            for method, sargs in METHODS:
                cfg = PipelineConfig(arch=arch, platforms=("f32", "bf16"),
                                     selector=method, selector_args=sargs,
                                     steps=N_STEPS, seq_len=32, batch=4,
                                     interval_steps=2.5, seed=0)
                metrics = Pipeline(cfg, store).run()["metrics"]
                for plat, m in metrics["platforms"].items():
                    rows.append((
                        f"prediction_error/{arch}/{method}/{plat}",
                        m["predicted_s"] * 1e6,
                        f"error={m['error']:+.3f};"
                        f"actual_us={m['actual_s']*1e6:.0f}"))
    return rows
