"""Paper Fig. 5: full-runtime prediction error by platform × method.

Random vs K-means nuggets, validated natively on two 'platforms' (f32 vs
bf16 compute — the container's stand-ins for distinct machines), without any
simulation.  Reproduces the qualitative findings: errors vary by workload,
no method dominates, per-platform errors differ."""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import Row
from repro.configs import get_config, reduced
from repro.core import (KMeansSelector, RandomSelector, ReplayEngine,
                        create_nuggets, measure_full_run, predict_total_time,
                        prediction_error)
from repro.train import Trainer

ARCHS = ["olmoe-1b-7b", "qwen3-1.7b"]
N_STEPS = 28


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ARCHS:
        base = reduced(get_config(arch))
        trainers = {}
        for plat, dt in (("f32", "float32"), ("bf16", "bfloat16")):
            cfg = dataclasses.replace(base, compute_dtype=dt)
            tr = Trainer(cfg, seq_len=32, batch=4, interval_steps=2.5,
                         seed=0, donate=False)
            tr.run(N_STEPS)
            trainers[plat] = tr
        prof = trainers["f32"].profile()
        for method, sel in (("random", RandomSelector(n_samples=8, seed=0)),
                            ("kmeans", KMeansSelector(seed=0))):
            selection = sel.select(prof)
            nugs = create_nuggets(prof, selection, warmup_intervals=1)
            for plat, tr in trainers.items():
                runner = tr.make_runner()
                eng = ReplayEngine(runner, prof)
                res = eng.replay_all(nugs)
                pred = predict_total_time(prof, res)
                actual = measure_full_run(runner, N_STEPS)
                err = prediction_error(pred, actual)
                rows.append((f"prediction_error/{arch}/{method}/{plat}",
                             pred * 1e6,
                             f"error={err:+.3f};actual_us={actual*1e6:.0f}"))
    return rows
