"""Paper Figs. 7-10: error in predicted *speedup* between platform pairs.

Platform axes mirror the paper's ISA-vs-microarchitecture study:
- dtype (f32 vs bf16)        — the 'ISA' axis (numerics/codegen change),
- attention impl/chunk size  — the 'microarchitecture' axis (same math,
  different execution schedule).

Reports per-pair |predicted speedup - true speedup| / true speedup for
Random and K-means sample sets, and the consistency summary the paper
identifies as the key quality signal.  Driven by the artifact pipeline:
the profile and per-platform baselines are cached across methods."""
from __future__ import annotations

import tempfile
from typing import List

from benchmarks.common import Row
from repro.pipeline import Pipeline, PipelineConfig

N_STEPS = 24
PLATFORMS = ("f32-chunk16", "bf16-chunk16", "f32-ref")

METHODS = (("random", {"n_samples": 6, "seed": 0}),
           ("kmeans", {"seed": 0}))


def _axis(pair: str) -> str:
    if "f32-chunk16|bf16-chunk16" in pair:
        return "dtype"
    if "f32-chunk16|f32-ref" in pair:
        return "impl"
    return "both"


def run() -> List[Row]:
    rows: List[Row] = []
    with tempfile.TemporaryDirectory(prefix="bench-speedup-") as store:
        for method, sargs in METHODS:
            cfg = PipelineConfig(arch="qwen3-1.7b", platforms=PLATFORMS,
                                 selector=method, selector_args=sargs,
                                 steps=N_STEPS, seq_len=32, batch=4,
                                 interval_steps=2.5, seed=0)
            metrics = Pipeline(cfg, store).run()["metrics"]
            for e in metrics["speedup_errors"]:
                rows.append((f"speedup_pred/{method}/{e['pair']}",
                             e["abs_speedup_error"] * 1e6,
                             f"axis={_axis(e['pair'])};"
                             f"true={e['true_speedup']:.3f};"
                             f"pred={e['pred_speedup']:.3f}"))
            rep = metrics["consistency"]
            rows.append((f"speedup_pred/{method}/consistency",
                         rep["error_spread"] * 1e6,
                         f"mean_abs_err={rep['mean_abs_error']:.3f};"
                         f"consistent={rep['consistent']}"))
    return rows
