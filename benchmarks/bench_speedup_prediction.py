"""Paper Figs. 7-10: error in predicted *speedup* between platform pairs.

Platform axes mirror the paper's ISA-vs-microarchitecture study:
- dtype (f32 vs bf16)        — the 'ISA' axis (numerics/codegen change),
- attention impl/chunk size  — the 'microarchitecture' axis (same math,
  different execution schedule).

Reports per-pair |predicted speedup - true speedup| / true speedup for
Random and K-means sample sets, and the consistency summary the paper
identifies as the key quality signal."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List

from benchmarks.common import Row
from repro.configs import get_config, reduced
from repro.core import (KMeansSelector, RandomSelector, ReplayEngine,
                        PlatformResult, consistency_report, create_nuggets,
                        measure_full_run, predict_total_time,
                        speedup_error_matrix)
from repro.train import Trainer

N_STEPS = 24


def _platforms(base):
    return {
        "f32-chunk16": dataclasses.replace(base, compute_dtype="float32",
                                           attn_chunk=16),
        "bf16-chunk16": dataclasses.replace(base, compute_dtype="bfloat16",
                                            attn_chunk=16),
        "f32-ref": dataclasses.replace(base, compute_dtype="float32",
                                       attention_impl="reference"),
    }


def run() -> List[Row]:
    rows: List[Row] = []
    base = reduced(get_config("qwen3-1.7b"))
    trainers = {}
    for name, cfg in _platforms(base).items():
        tr = Trainer(cfg, seq_len=32, batch=4, interval_steps=2.5, seed=0,
                     donate=False)
        tr.run(N_STEPS)
        trainers[name] = tr
    prof = next(iter(trainers.values())).profile()

    for method, sel in (("random", RandomSelector(n_samples=6, seed=0)),
                        ("kmeans", KMeansSelector(seed=0))):
        selection = sel.select(prof)
        nugs = create_nuggets(prof, selection, warmup_intervals=1)
        plats: List[PlatformResult] = []
        for name, tr in trainers.items():
            runner = tr.make_runner()
            eng = ReplayEngine(runner, prof)
            res = eng.replay_all(nugs)
            plats.append(PlatformResult(
                name, predict_total_time(prof, res),
                measure_full_run(runner, N_STEPS)))
        for e in speedup_error_matrix(plats):
            kind = ("dtype" if "f32-chunk16|bf16-chunk16" in e["pair"]
                    else "impl" if "f32-chunk16|f32-ref" in e["pair"]
                    else "both")
            rows.append((f"speedup_pred/{method}/{e['pair']}",
                         e["abs_speedup_error"] * 1e6,
                         f"axis={kind};true={e['true_speedup']:.3f};"
                         f"pred={e['pred_speedup']:.3f}"))
        rep = consistency_report(plats)
        rows.append((f"speedup_pred/{method}/consistency",
                     rep["error_spread"] * 1e6,
                     f"mean_abs_err={rep['mean_abs_error']:.3f};"
                     f"consistent={rep['consistent']}"))
    return rows
