"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def time_fn(fn: Callable[[], None], *, repeats: int = 5,
            warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def fmt_rows(rows: Iterable[Row]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
