"""Pipeline-stage wall times + cache behaviour (run-manifest trajectory).

Runs the artifact pipeline three times on a reduced config: a serial cold
pass (per-stage compute cost), a warm pass against the same store (cache-
load cost, must hit on every stage), and a parallel cold pass against a
fresh store with the DAG scheduler at ``PARALLEL_WORKERS`` threads.  The
parallel pass must reproduce the serial stage keys exactly — the artifact
addresses are input-addressed, so any divergence is a determinism bug.

The parallel speedup comes from overlapping independent stages (profile
and the per-platform baselines have no edges between them, and their cost
is dominated by XLA compilation + step execution, which release the GIL),
so it scales with the host's core count: on a single-core host wall time
is conserved (speedup ~1x); with >=2 cores the profile/baseline overlap
alone bounds it near ``total / max(profile, baselines)``.  ``host_cpus``
is recorded alongside the speedup so trajectory entries are comparable.
``run.py`` appends the summary (``LAST_ENTRY``, including
``parallel_speedup_x``) to ``BENCH_pipeline.json`` so perf history
accumulates across benchmark invocations."""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List, Optional

from benchmarks.common import Row
from repro.pipeline import Pipeline, PipelineConfig

N_STEPS = 16
PARALLEL_WORKERS = 4

# summary of the most recent run() for the BENCH_pipeline.json trajectory
LAST_ENTRY: Optional[Dict] = None


def _summary(manifest: Dict) -> Dict:
    return {
        "wall_s": manifest["wall_s"],
        "workers": manifest.get("workers", 0),
        "cache_hits": manifest["cache_hits"],
        "cache_misses": manifest["cache_misses"],
        "stage_wall_s": {s["stage"]: s["wall_s"]
                         for s in manifest["stages"]},
        "stage_cache_hit": {s["stage"]: s["cache_hit"]
                            for s in manifest["stages"]},
    }


def _cfg(workers: int = 0) -> PipelineConfig:
    return PipelineConfig(arch="olmoe-1b-7b", platforms=("f32",),
                          selector="random",
                          selector_args={"n_samples": 4, "seed": 0},
                          steps=N_STEPS, seq_len=32, batch=2,
                          interval_steps=2.0, seed=0, workers=workers)


def run() -> List[Row]:
    global LAST_ENTRY
    rows: List[Row] = []
    with tempfile.TemporaryDirectory(prefix="bench-pipe-") as store:
        cold = Pipeline(_cfg(), store).run()
        warm = Pipeline(_cfg(), store).run()
    with tempfile.TemporaryDirectory(prefix="bench-pipe-par-") as store:
        par = Pipeline(_cfg(PARALLEL_WORKERS), store).run()
    assert warm["cache_misses"] == 0, \
        f"warm pipeline re-ran stages: {warm['stages']}"
    serial_keys = {s["stage"]: s["key"] for s in cold["stages"]}
    par_keys = {s["stage"]: s["key"] for s in par["stages"]}
    assert serial_keys == par_keys, \
        f"parallel run diverged from serial: {serial_keys} != {par_keys}"
    for label, manifest in (("cold", cold), ("warm", warm),
                            ("cold_parallel", par)):
        for s in manifest["stages"]:
            rows.append((f"pipeline/{label}/{s['stage']}",
                         s["wall_s"] * 1e6, f"hit={s['cache_hit']}"))
        rows.append((f"pipeline/{label}/total", manifest["wall_s"] * 1e6,
                     f"hits={manifest['cache_hits']};"
                     f"misses={manifest['cache_misses']}"))
    speedup = cold["wall_s"] / max(par["wall_s"], 1e-9)
    rows.append((f"pipeline/parallel_speedup", speedup,
                 f"workers={PARALLEL_WORKERS}"))
    # hit-path integrity cost: the warm pass re-hashes every payload
    # against the digests recorded at commit — report it as a fraction
    # of warm wall time (hash-on-commit is amortized into the cold miss)
    wsc = warm["obs"]["store_counters"]
    verified, verify_s = wsc["verified"], wsc["verify_s"]
    assert verified == len(warm["stages"]), \
        f"warm pass verified {verified}/{len(warm['stages'])} artifacts"
    verify_frac = verify_s / max(warm["wall_s"], 1e-9)
    rows.append(("pipeline/warm/verify_total", verify_s * 1e6,
                 f"artifacts={verified};frac_of_warm={verify_frac:.2e}"))
    rows.append(("pipeline/warm/verify_per_artifact",
                 verify_s / max(verified, 1) * 1e6,
                 f"artifacts={verified}"))
    LAST_ENTRY = {"cold": _summary(cold), "warm": _summary(warm),
                  "cold_parallel": _summary(par),
                  "parallel_speedup_x": speedup,
                  "parallel_workers": PARALLEL_WORKERS,
                  "warm_verify_s": verify_s,
                  "warm_verified_artifacts": verified,
                  "warm_verify_frac": verify_frac,
                  "host_cpus": os.cpu_count()}
    return rows
