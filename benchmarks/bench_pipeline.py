"""Pipeline-stage wall times + cache behaviour (run-manifest trajectory).

Runs the artifact pipeline twice against one store on a reduced config:
the cold pass measures per-stage compute cost, the warm pass measures
cache-load cost and must hit on every stage.  ``run.py`` appends the
summary (``LAST_ENTRY``) to ``BENCH_pipeline.json`` so perf history
accumulates across benchmark invocations."""
from __future__ import annotations

import tempfile
from typing import Dict, List, Optional

from benchmarks.common import Row
from repro.pipeline import Pipeline, PipelineConfig

N_STEPS = 16

# summary of the most recent run() for the BENCH_pipeline.json trajectory
LAST_ENTRY: Optional[Dict] = None


def _summary(manifest: Dict) -> Dict:
    return {
        "wall_s": manifest["wall_s"],
        "cache_hits": manifest["cache_hits"],
        "cache_misses": manifest["cache_misses"],
        "stage_wall_s": {s["stage"]: s["wall_s"]
                         for s in manifest["stages"]},
        "stage_cache_hit": {s["stage"]: s["cache_hit"]
                            for s in manifest["stages"]},
    }


def run() -> List[Row]:
    global LAST_ENTRY
    rows: List[Row] = []
    with tempfile.TemporaryDirectory(prefix="bench-pipe-") as store:
        cfg = PipelineConfig(arch="olmoe-1b-7b", platforms=("f32",),
                             selector="random",
                             selector_args={"n_samples": 4, "seed": 0},
                             steps=N_STEPS, seq_len=32, batch=2,
                             interval_steps=2.0, seed=0)
        cold = Pipeline(cfg, store).run()
        warm = Pipeline(cfg, store).run()
    assert warm["cache_misses"] == 0, \
        f"warm pipeline re-ran stages: {warm['stages']}"
    for label, manifest in (("cold", cold), ("warm", warm)):
        for s in manifest["stages"]:
            rows.append((f"pipeline/{label}/{s['stage']}",
                         s["wall_s"] * 1e6, f"hit={s['cache_hit']}"))
        rows.append((f"pipeline/{label}/total", manifest["wall_s"] * 1e6,
                     f"hits={manifest['cache_hits']};"
                     f"misses={manifest['cache_misses']}"))
    LAST_ENTRY = {"cold": _summary(cold), "warm": _summary(warm)}
    return rows
