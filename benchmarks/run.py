"""Benchmark harness: one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV.  Select with --only substr.

The pipeline suite additionally appends its run-manifest summary (stage
wall times + cache-hit counts) to ``BENCH_pipeline.json`` so perf history
accumulates across invocations."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_hook_overhead, bench_interval_overhead,
                        bench_kernels, bench_model_accuracy,
                        bench_pipeline, bench_prediction_error,
                        bench_roofline, bench_speedup_prediction,
                        bench_sync_scaling)
from benchmarks.common import fmt_rows

SUITES = [
    ("interval_overhead(Fig2-3)", bench_interval_overhead),
    ("sync_scaling(Fig4)", bench_sync_scaling),
    ("prediction_error(Fig5)", bench_prediction_error),
    ("hook_overhead(Fig6)", bench_hook_overhead),
    ("speedup_prediction(Fig7-10)", bench_speedup_prediction),
    ("model_accuracy(Fig11)", bench_model_accuracy),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("pipeline(manifest)", bench_pipeline),
]

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_pipeline.json")


def write_trajectory(path: str = TRAJECTORY) -> None:
    """Append the pipeline suite's manifest summary to the trajectory file."""
    if bench_pipeline.LAST_ENTRY is None:
        return
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append({"ts": time.time(), **bench_pipeline.LAST_ENTRY})
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# pipeline trajectory -> {os.path.abspath(path)} "
          f"({len(history)} entries)", flush=True)
    speedup = bench_pipeline.LAST_ENTRY.get("parallel_speedup_x")
    if speedup is not None:
        print(f"# pipeline parallel speedup: {speedup:.2f}x "
              f"(workers={bench_pipeline.PARALLEL_WORKERS})", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
            print(fmt_rows(rows), flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    write_trajectory()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
