"""Roofline rows from the dry-run artifacts (EXPERIMENTS.md §Roofline).
One CSV row per (arch × shape × mesh) cell; requires artifacts/dryrun/
(run ``python -m repro.launch.dryrun --all`` first).  Cells not yet compiled
are reported as missing rather than failing the bench run."""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import Row
from repro.launch.roofline import analyze_cell, load_cells

DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def run() -> List[Row]:
    rows: List[Row] = []
    if not os.path.isdir(DIR):
        return [("roofline/missing", 0.0,
                 "run: PYTHONPATH=src python -m repro.launch.dryrun --all")]
    for cell in load_cells(DIR):
        name = f"roofline/{cell.get('cell', '?')}"
        status = cell.get("status", "?")
        if status.startswith("skipped"):
            rows.append((name, 0.0, status))
            continue
        if status != "ok":
            rows.append((name, 0.0, f"status={status}"))
            continue
        r = analyze_cell(cell)
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((name, bound_s * 1e6,
                     f"dominant={r['dominant']};"
                     f"compute_s={r['compute_s']:.2e};"
                     f"memory_s={r['memory_s']:.2e};"
                     f"collective_s={r['collective_s']:.2e};"
                     f"useful_flops_ratio={r['useful_ratio']:.2f};"
                     f"roofline_frac={r['roofline_fraction']:.2f}"))
    return rows
