"""Paper Fig. 6: marker-hook execution fraction per nugget, normalized to
total block executions — plus the low-overhead marker search's effect.

The paper's cutoff guidance: markers executing >10%% (single-stream) of all
block executions distort validation.  We report the fraction for the true
end marker vs the searched low-overhead marker and the precision cost."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.configs import get_config, reduced
from repro.core import (RandomSelector, create_nuggets, marker_hook_fraction,
                        plan_markers)
from repro.train import Trainer


def run() -> List[Row]:
    rows: List[Row] = []
    cfg = reduced(get_config("olmoe-1b-7b"))
    tr = Trainer(cfg, seq_len=32, batch=4, interval_steps=2.5, seed=0,
                 donate=False)
    tr.run(24)
    prof = tr.profile()
    sel = RandomSelector(n_samples=6, seed=0).select(prof)
    step_uow = prof.step_uow
    for idx in sel.interval_ids:
        plain = plan_markers(prof, idx, search_distance=0.0)
        cheap = plan_markers(prof, idx, search_distance=0.4 * step_uow)
        rows.append((
            f"hook_overhead/interval{idx}/end_marker",
            plain.hook_fraction * 1e6,      # fraction (scaled for CSV)
            f"frac={plain.hook_fraction:.4f};"
            f"block={prof.table.names[plain.end.block]}"))
        rows.append((
            f"hook_overhead/interval{idx}/low_overhead_marker",
            cheap.hook_fraction * 1e6,
            f"frac={cheap.hook_fraction:.4f};"
            f"precision_loss_uow={cheap.precision_loss_uow:.0f};"
            f"block={prof.table.names[cheap.end.block]}"))
    return rows
