"""Paper Fig. 6: marker-hook execution fraction per nugget, normalized to
total block executions — plus the low-overhead marker search's effect.

The paper's cutoff guidance: markers executing >10%% (single-stream) of all
block executions distort validation.  We report the fraction for the true
end marker vs the searched low-overhead marker and the precision cost.

This suite also enforces the ``repro.obs`` overhead budget: with tracing
disabled (the default), the per-step observability calls the Trainer makes
(one disabled span check plus a counter/gauge/histogram bundle per step)
must cost under 2 percent of a median training step.  The per-call costs
are micro-benchmarked and compared against the measured step time; breach
raises, failing the harness.

The fault-tolerance machinery's disabled path rides the same gate: with
no ``REPRO_FAULTS`` the injector is ``None`` (one env lookup per run, an
is-None branch per stage) and without a journal the stage driver's
``getattr`` probe is the whole cost.  These are per-*stage* costs counted
here per-*step* — a deliberate over-estimate — and the combined obs +
fault disabled bundle must still clear the 2 percent budget."""
from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro import obs
from repro.configs import get_config, reduced
from repro.core import (RandomSelector, create_nuggets, marker_hook_fraction,
                        plan_markers)
from repro.faults import FaultInjector
from repro.train import Trainer

OBS_BUDGET_FRACTION = 0.02      # disabled-path obs cost per step, max

# what Trainer._post_step does per step: 1 counter inc, 1 histogram
# observation, 2 gauge writes — plus one disabled span() check to cover
# span-wrapped hot loops
OBS_CALLS_PER_STEP = {"count": 1, "observe": 1, "record": 2, "span": 1}

# disabled fault-tolerance checks, conservatively billed per step even
# though they really fire per stage (is-None branch, journal getattr
# probe) or once per run (env spec lookup)
FAULT_CALLS_PER_STEP = {"from_env": 1, "injector_check": 1,
                        "journal_check": 1}


def _per_call_ns(fn, n: int = 20_000) -> float:
    for _ in range(n // 10):                 # warmup
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e9


def obs_disabled_costs() -> dict:
    """Nanoseconds per disabled-path obs call, micro-benchmarked."""
    obs.configure(trace=False)
    m = obs.metrics()

    def spanned():
        with obs.span("bench.noop"):
            pass

    costs = {
        "span": _per_call_ns(spanned),
        "count": _per_call_ns(lambda: m.count("bench.noop_c")),
        "observe": _per_call_ns(lambda: m.observe("bench.noop_h", 1.0)),
        "record": _per_call_ns(lambda: m.record("bench.noop_g", 1.0)),
    }
    return costs


def fault_disabled_costs() -> dict:
    """Nanoseconds per disabled fault-tolerance check: env construction
    with no spec set (returns None), the scheduler/store is-None branch,
    and the stage driver's journal getattr probe."""
    env = {k: v for k, v in os.environ.items() if k != "REPRO_FAULTS"}
    injector = None
    probe = object()                 # ctx without a journal_event attr
    sink = {"hits": 0}

    def check():
        if injector is not None:     # the store/scheduler hot branch
            sink["hits"] += 1

    return {
        "from_env": _per_call_ns(lambda: FaultInjector.from_env(env)),
        "injector_check": _per_call_ns(check),
        "journal_check": _per_call_ns(
            lambda: getattr(probe, "journal_event", None)),
    }


def obs_overhead_rows(step_s: float) -> List[Row]:
    """Budget rows + the <2%% gate against a measured step time."""
    costs = obs_disabled_costs()
    fcosts = fault_disabled_costs()
    obs_ns = sum(costs[k] * n for k, n in OBS_CALLS_PER_STEP.items())
    fault_ns = sum(fcosts[k] * n for k, n in FAULT_CALLS_PER_STEP.items())
    per_step_ns = obs_ns + fault_ns
    frac = per_step_ns * 1e-9 / max(step_s, 1e-12)
    rows: List[Row] = [
        ("hook_overhead/obs_disabled_span", costs["span"] / 1e3,
         f"ns_per_call={costs['span']:.0f}"),
        ("hook_overhead/obs_disabled_metrics", sum(
            costs[k] * n for k, n in OBS_CALLS_PER_STEP.items()
            if k != "span") / 1e3,
         "ns_per_step_bundle={:.0f}".format(sum(
             costs[k] * n for k, n in OBS_CALLS_PER_STEP.items()
             if k != "span"))),
        ("hook_overhead/fault_disabled_checks", fault_ns / 1e3,
         "ns_per_step_bundle={:.0f};from_env={:.0f};check={:.0f};"
         "journal={:.0f}".format(fault_ns, fcosts["from_env"],
                                 fcosts["injector_check"],
                                 fcosts["journal_check"])),
        ("hook_overhead/obs_step_fraction", frac * 1e6,
         f"frac={frac:.2e};budget={OBS_BUDGET_FRACTION};"
         f"step_ms={step_s * 1e3:.2f}"),
    ]
    if frac >= OBS_BUDGET_FRACTION:
        raise RuntimeError(
            f"obs+fault disabled-path overhead {frac:.2%} of a training "
            f"step breaches the {OBS_BUDGET_FRACTION:.0%} budget "
            f"(obs {obs_ns:.0f}ns + fault {fault_ns:.0f}ns per step, "
            f"step {step_s:.4f}s)")
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    cfg = reduced(get_config("olmoe-1b-7b"))
    tr = Trainer(cfg, seq_len=32, batch=4, interval_steps=2.5, seed=0,
                 donate=False)
    tr.run(24)
    prof = tr.profile()
    sel = RandomSelector(n_samples=6, seed=0).select(prof)
    step_uow = prof.step_uow
    for idx in sel.interval_ids:
        plain = plan_markers(prof, idx, search_distance=0.0)
        cheap = plan_markers(prof, idx, search_distance=0.4 * step_uow)
        rows.append((
            f"hook_overhead/interval{idx}/end_marker",
            plain.hook_fraction * 1e6,      # fraction (scaled for CSV)
            f"frac={plain.hook_fraction:.4f};"
            f"block={prof.table.names[plain.end.block]}"))
        rows.append((
            f"hook_overhead/interval{idx}/low_overhead_marker",
            cheap.hook_fraction * 1e6,
            f"frac={cheap.hook_fraction:.4f};"
            f"precision_loss_uow={cheap.precision_loss_uow:.0f};"
            f"block={prof.table.names[cheap.end.block]}"))
    # steady-state step time (skip the compile step) anchors the obs budget
    step_s = float(np.median(tr.step_times[1:]))
    rows.extend(obs_overhead_rows(step_s))
    return rows
