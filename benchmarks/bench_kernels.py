"""Framework-layer kernel benchmarks: chunked (flash-style) vs reference
attention and chunked-SSD vs sequential recurrence on this host, plus
Pallas-kernel (interpret-mode) correctness spot checks."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.kernels import ops, ref
from repro.models.attention import HeadLayout, attend_chunked, attend_reference
from repro.configs.base import AttnConfig
from repro.models.ssm import ssd_chunked, ssd_reference


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # attention: reference vs chunked at growing seq (memory-bound XLA path)
    B, H, KV, hd = 1, 4, 2, 32
    layout = HeadLayout.make(AttnConfig(H, KV, hd), 1)
    for S in (256, 1024):
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        k = jnp.repeat(k, layout.repeat, 2) if layout.repeat > 1 else k
        v = jnp.repeat(jax.random.normal(ks[2], (B, S, KV, hd)),
                       layout.repeat, 2) if layout.repeat > 1 else \
            jax.random.normal(ks[2], (B, S, KV, hd))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        w = jnp.int32(-1)
        f_ref = jax.jit(lambda q, k, v: attend_reference(
            q, k, v, pos, pos, layout, causal=True, window=w))
        f_chk = jax.jit(lambda q, k, v: attend_chunked(
            q, k, v, pos, pos, layout, causal=True, window=w,
            q_chunk=256, kv_chunk=256))
        f_skp = jax.jit(lambda q, k, v: attend_chunked(
            q, k, v, pos, pos, layout, causal=True, window=w,
            q_chunk=256, kv_chunk=256, causal_skip=True))
        o1 = f_ref(q, k, v); o2 = f_chk(q, k, v); o3 = f_skp(q, k, v)
        err = float(jnp.max(jnp.abs(o1 - o2)))
        err_s = float(jnp.max(jnp.abs(o1 - o3)))
        t1 = time_fn(lambda: jax.block_until_ready(f_ref(q, k, v)))
        t2 = time_fn(lambda: jax.block_until_ready(f_chk(q, k, v)))
        t3 = time_fn(lambda: jax.block_until_ready(f_skp(q, k, v)))
        rows.append((f"kernels/attn_reference/S={S}", t1 * 1e6, "oracle"))
        rows.append((f"kernels/attn_chunked/S={S}", t2 * 1e6,
                     f"speedup={t1 / t2:.2f}x;err={err:.1e}"))
        rows.append((f"kernels/attn_causal_skip/S={S}", t3 * 1e6,
                     f"speedup={t1 / t3:.2f}x;err={err_s:.1e}"))

    # SSD: sequential recurrence vs chunked matmul form
    Bb, S, nh, hp, N = 2, 512, 4, 32, 16
    xh = jax.random.normal(ks[3], (Bb, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (Bb, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[5], (nh,)))
    Bp = jax.random.normal(ks[6], (Bb, S, N))
    Cp = jax.random.normal(ks[7], (Bb, S, N))
    f_seq = jax.jit(lambda: ssd_reference(xh, dt, A, Bp, Cp)[0])
    f_chk = jax.jit(lambda: ssd_chunked(xh, dt, A, Bp, Cp, 128)[0])
    e = float(jnp.max(jnp.abs(f_seq() - f_chk())))
    t1 = time_fn(lambda: jax.block_until_ready(f_seq()))
    t2 = time_fn(lambda: jax.block_until_ready(f_chk()))
    rows.append(("kernels/ssd_sequential", t1 * 1e6, "oracle"))
    rows.append(("kernels/ssd_chunked", t2 * 1e6,
                 f"speedup={t1 / t2:.2f}x;err={e:.1e}"))

    # Pallas interpret-mode spot correctness (full sweeps in tests/)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 1, 16))
    v = jax.random.normal(ks[2], (1, 64, 1, 16))
    o = ops.flash_attention(q, k, v, group=2, causal=True, bq=32, bk=32)
    w = ref.flash_attention_ref(q, k, v, group=2, causal=True)
    rows.append(("kernels/pallas_flash_interpret", 0.0,
                 f"maxerr={float(jnp.max(jnp.abs(o - w))):.1e}"))
    return rows
