"""Equivalence + regression tests for the vectorized interval pipeline.

The batch (``add_steps``), parallel (chunked thread-pool) and deferred
(``defer=True``) build paths must produce Profiles that are bit-for-bit
identical to the legacy per-step ``add_step`` replay — same interval
boundaries, same float BBVs (including pro-rated virtual contributions),
same stamps/hits/markers.  Streams are randomized: mixed step kinds,
dynamic aux values, interval sizes that make single hooks span multiple
boundaries, and interval sizes much larger than a step.
"""
import numpy as np
import pytest

from repro.core.intervals import (IntervalBuilder, build_profile,
                                  build_profile_from_steps)
from repro.core.intervals_vec import analyze_steps_parallel, as_steps
from repro.core.registry import BlockDef, BlockTable, Segment


def make_table(rng, n_blocks=8, n_virtual=2, kinds=("default",)):
    blocks = [BlockDef(f"b{i}", cost_ops=float(rng.integers(1, 50)))
              for i in range(n_blocks)]
    for v in range(n_virtual):
        blocks.append(BlockDef(f"v{v}", cost_ops=0.0, virtual=True,
                               dyn_key=f"aux{v}",
                               dyn_index=v if v % 2 == 0 else -1))
    programs = {}
    for k in kinds:
        segs = []
        for _ in range(int(rng.integers(1, 4))):
            pat = tuple(int(x) for x in
                        rng.integers(0, n_blocks, rng.integers(1, 5)))
            segs.append(Segment(pat, int(rng.integers(1, 4))))
        programs[k] = segs
    return BlockTable(blocks, programs[kinds[0]], programs)


def make_steps(rng, n_steps, kinds, dyn_prob=0.5):
    steps = []
    for _ in range(n_steps):
        k = kinds[int(rng.integers(0, len(kinds)))]
        dyn = None
        if rng.random() < dyn_prob:
            dyn = {"aux0": rng.random(4), "aux1": float(rng.random())}
        steps.append((k, dyn))
    return steps


def assert_profiles_equal(p, q):
    assert p.n_intervals == q.n_intervals
    assert p.n_steps == q.n_steps
    assert p.total_uow == q.total_uow
    for a, b in zip(p.intervals, q.intervals):
        assert a.idx == b.idx
        assert a.start_uow == b.start_uow and a.end_uow == b.end_uow
        assert a.start_step == b.start_step and a.end_step == b.end_step
        assert a.end_marker == b.end_marker
        assert np.array_equal(a.bbv, b.bbv), \
            f"bbv mismatch at interval {a.idx}"
        assert np.array_equal(a.stamps, b.stamps)
        assert np.array_equal(a.hits_at_stamp, b.hits_at_stamp)
    assert set(p.dyn_history) == set(q.dyn_history)
    for k in p.dyn_history:
        assert np.array_equal(p.dyn_history[k], q.dyn_history[k])


@pytest.mark.parametrize("seed", range(12))
def test_batch_and_parallel_match_legacy(seed):
    rng = np.random.default_rng(seed)
    kinds = ("default",) if seed % 3 == 0 else ("default", "prefill", "decode")
    table = make_table(rng, n_blocks=int(rng.integers(3, 10)),
                       n_virtual=int(rng.integers(0, 3)), kinds=kinds)
    steps = make_steps(rng, int(rng.integers(5, 60)), kinds)
    step_uow = max(table.step_uow(k) for k in kinds)
    # interval sizes spanning: many closes per step, ~1 per step, and
    # intervals covering many steps
    for frac in (0.13, 0.61, 1.7, 7.3):
        iu = max(step_uow * frac, 1.0)
        legacy = build_profile(table, iu, steps, method="legacy")
        batch = build_profile(table, iu, steps, method="batch")
        assert_profiles_equal(legacy, batch)
        par = build_profile(table, iu, steps, method="parallel",
                            chunk_steps=int(rng.integers(1, 9)))
        assert_profiles_equal(legacy, par)


def test_single_hook_spans_multiple_boundaries():
    table = BlockTable([BlockDef("big", cost_ops=100.0),
                        BlockDef("small", cost_ops=1.0)],
                       [Segment((1, 0, 1), 2)])
    steps = as_steps(n_steps=7)
    legacy = build_profile(table, 30.0, steps, method="legacy")
    batch = build_profile(table, 30.0, steps, method="batch")
    par = build_profile(table, 30.0, steps, method="parallel", chunk_steps=2)
    assert_profiles_equal(legacy, batch)
    assert_profiles_equal(legacy, par)
    assert legacy.n_intervals > 0


def test_mixed_incremental_paths_match():
    rng = np.random.default_rng(123)
    table = make_table(rng, kinds=("default", "decode"))
    steps = make_steps(rng, 40, ("default", "decode"))
    iu = table.step_uow() * 0.9

    legacy = IntervalBuilder(table, iu)
    for k, d in steps:
        legacy.add_step(d, kind=k)

    mixed = IntervalBuilder(table, iu)
    for k, d in steps[:7]:
        mixed.add_step(d, kind=k)
    mixed.add_steps(steps[7:23])
    for k, d in steps[23:29]:
        mixed.add_step(d, kind=k)
    mixed.add_steps(steps[29:])

    assert_profiles_equal(legacy.finalize(), mixed.finalize())


def test_deferred_analysis_matches_eager():
    rng = np.random.default_rng(7)
    table = make_table(rng, kinds=("default", "prefill"))
    steps = make_steps(rng, 35, ("default", "prefill"))
    iu = table.step_uow() * 1.3

    eager = IntervalBuilder(table, iu)
    for k, d in steps:
        eager.add_step(d, kind=k)

    deferred = IntervalBuilder(table, iu, defer=True)
    for k, d in steps:
        deferred.add_step(d, kind=k)
    assert deferred.intervals == []          # nothing analyzed yet
    assert len(deferred.step_log) == len(steps)

    assert_profiles_equal(eager.finalize(), deferred.finalize())


def test_absorb_chunks_incrementally():
    rng = np.random.default_rng(11)
    table = make_table(rng)
    steps = make_steps(rng, 30, ("default",))
    iu = table.step_uow() * 0.77
    legacy = build_profile(table, iu, steps, method="legacy")
    b = IntervalBuilder(table, iu)
    for res, chunk in analyze_steps_parallel(table, iu, steps,
                                             chunk_steps=4, max_workers=3):
        b.absorb(res, chunk)
    assert_profiles_equal(legacy, b.finalize())


def test_build_profile_from_steps_methods_agree():
    rng = np.random.default_rng(3)
    table = make_table(rng, n_virtual=1)
    dyns = [{"aux0": rng.random(4)} if i % 3 else None for i in range(25)]
    p_leg = build_profile_from_steps(table, 25, table.step_uow() * 2.1,
                                     dyn_per_step=dyns, method="legacy")
    p_bat = build_profile_from_steps(table, 25, table.step_uow() * 2.1,
                                     dyn_per_step=dyns, method="batch")
    p_par = build_profile_from_steps(table, 25, table.step_uow() * 2.1,
                                     dyn_per_step=dyns, method="parallel")
    assert_profiles_equal(p_leg, p_bat)
    assert_profiles_equal(p_leg, p_par)


def test_expand_memoized_once_per_kind():
    rng = np.random.default_rng(5)
    table = make_table(rng, kinds=("default", "prefill", "decode"))
    steps = make_steps(rng, 50, ("default", "prefill", "decode"), dyn_prob=0)
    for method in ("legacy", "batch", "parallel"):
        build_profile(table, table.step_uow() * 0.8, steps, method=method)
    # memoization: each kind's stream was materialized exactly once ever,
    # no matter how many builders/paths/steps consumed it
    assert all(c == 1 for c in table._expand_count.values()), \
        table._expand_count
    assert set(table._expand_count) == {"default", "prefill", "decode"}


@pytest.mark.parametrize("seed", range(4))
def test_finalize_parallel_matches_serial_finalize(seed):
    """``finalize_parallel`` on a deferred builder is bit-for-bit identical
    to the serial ``finalize`` — the chunk merge is associative."""
    rng = np.random.default_rng(100 + seed)
    kinds = ("default", "prefill")
    table = make_table(rng, kinds=kinds)
    steps = make_steps(rng, int(rng.integers(20, 70)), kinds)
    iu = table.step_uow() * float(rng.uniform(0.3, 3.0))

    serial = IntervalBuilder(table, iu, defer=True)
    for k, d in steps:
        serial.add_step(d, kind=k)

    par = IntervalBuilder(table, iu, defer=True)
    for k, d in steps:
        par.add_step(d, kind=k)
    assert par.deferred and par.intervals == []

    assert_profiles_equal(
        serial.finalize(),
        par.finalize_parallel(chunk_steps=int(rng.integers(2, 9)),
                              max_workers=3))


def test_finalize_parallel_after_eager_prefix():
    """The sharded finalize positions its chunks at the builder's current
    state (global counter, step index, cumulative hits), so it is exact
    even when a prefix of the stream was already analyzed eagerly."""
    rng = np.random.default_rng(42)
    table = make_table(rng)
    steps = make_steps(rng, 40, ("default",))
    iu = table.step_uow() * 0.9

    legacy = IntervalBuilder(table, iu)
    for k, d in steps:
        legacy.add_step(d, kind=k)

    b = IntervalBuilder(table, iu, defer=True)
    for k, d in steps[:13]:
        b.add_step(d, kind=k)
    b.finalize()                             # analyze the prefix
    for k, d in steps[13:]:
        b.add_step(d, kind=k)                # deferred suffix
    assert_profiles_equal(legacy.finalize(),
                          b.finalize_parallel(chunk_steps=5, max_workers=2))


def test_finalize_parallel_is_noop_when_fully_processed():
    rng = np.random.default_rng(8)
    table = make_table(rng)
    steps = make_steps(rng, 10, ("default",))
    b = IntervalBuilder(table, table.step_uow())
    for k, d in steps:
        b.add_step(d, kind=k)                # eager: nothing pending
    q = IntervalBuilder(table, table.step_uow())
    for k, d in steps:
        q.add_step(d, kind=k)
    assert_profiles_equal(q.finalize(), b.finalize_parallel(max_workers=4))


def test_step_log_records_full_stream():
    rng = np.random.default_rng(9)
    table = make_table(rng)
    steps = make_steps(rng, 12, ("default",))
    b = IntervalBuilder(table, table.step_uow())
    for k, d in steps[:5]:
        b.add_step(d, kind=k)
    b.add_steps(steps[5:])
    assert b.step_log == steps
