"""Multi-device integration: REAL sharded training/serving on an 8-device
host mesh (subprocess — the device count must be set before jax init).

Covers what the dry-run can't: numerics of the 2D-sharded step match the
single-device step, the instrumented profile is identical (binary
independence across meshes), and elastic restore works across mesh shapes.
"""
import json
import subprocess
import sys

import pytest

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced, ShapeConfig
from repro.core.blocks_lm import build_block_table
from repro.distributed.sharding import (logical_rules, params_shardings,
                                        use_rules)
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig, OptState
from repro.optim.schedule import constant
from repro.train.state import TrainState, init_train_state, make_train_step

cfg = reduced(get_config("qwen3-1.7b"))
B, S = 8, 32
key = jax.random.PRNGKey(0)
toks = np.asarray(jax.random.randint(key, (B, S), 0, cfg.vocab_size))
batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
opt = AdamWConfig(lr=1e-3)

# ---- single-device reference ------------------------------------------
m1 = build_model(cfg)
shape = ShapeConfig("t", "train", S, B)
t1 = build_block_table(m1, shape)
s1 = init_train_state(m1, key, opt, t1)
step1 = jax.jit(make_train_step(m1, opt, constant(1e-3), table=t1))
losses1 = []
for _ in range(3):
    s1, met, _ = step1(s1, batch)
    losses1.append(float(met["loss"]))

# ---- 4x2 mesh, 2D sharded ----------------------------------------------
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = logical_rules(mesh, mode="train")
with mesh, use_rules(plan):
    m2 = build_model(cfg, plan)
    t2 = build_block_table(m2, shape)
    s2 = init_train_state(m2, key, opt, t2)
    pshard = params_shardings(mesh, plan, m2.axes())
    rep = NamedSharding(mesh, P())
    st_shard = TrainState(rep, pshard, OptState(rep, pshard, pshard, pshard),
                          rep, jax.tree.map(lambda _: rep, s2.meter))
    bshard = {k: NamedSharding(mesh, plan.spec(("batch", "seq")))
              for k in batch}
    s2 = jax.device_put(s2, st_shard)
    sb = jax.device_put(batch, bshard)
    step2 = jax.jit(make_train_step(m2, opt, constant(1e-3), table=t2),
                    in_shardings=(st_shard, bshard))
    losses2 = []
    for _ in range(3):
        s2, met, _ = step2(s2, sb)
        losses2.append(float(met["loss"]))

# block tables identical across meshes (binary independence: same IR; the
# 2-way TP axis divides this arch's heads so no padding difference)
same_table = (t1.names == t2.names
              and np.allclose(t1.costs(), t2.costs(), rtol=1e-6))

print(json.dumps({
    "losses1": losses1,
    "losses2": losses2,
    "same_table": bool(same_table),
    "uow1": float(t1.step_uow()),
    "uow2": float(t2.step_uow()),
}))
"""


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    out = subprocess.run([sys.executable, "-c", _CHILD],
                         capture_output=True, text=True, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    d = json.loads(out.stdout.strip().splitlines()[-1])
    for a, b in zip(d["losses1"], d["losses2"]):
        assert abs(a - b) / abs(a) < 2e-2, (d["losses1"], d["losses2"])
    assert d["same_table"], "unit of work must be mesh-independent"
    assert d["uow1"] == d["uow2"]
