"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (assignment
requirement: assert_allclose against ref.py for every Pallas kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 2, 1, 16),
    (2, 96, 4, 2, 32),
    (1, 128, 8, 8, 64),
    (2, 40, 6, 2, 16),          # non-multiple-of-block seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, group=H // KV, causal=causal,
                              bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, group=H // KV, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 24])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = ops.flash_attention(q, k, v, group=2, causal=True, window=window,
                              bq=16, bk=16)
    want = ref.flash_attention_ref(q, k, v, group=2, causal=True,
                                   window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 1, 32, 2, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 4
    k = jax.random.normal(ks[1], (B, S, KV, hd)) * 4
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = ops.flash_attention(q, k, v, group=1, causal=True, cap=20.0,
                              bq=16, bk=16)
    want = ref.flash_attention_ref(q, k, v, group=1, causal=True, cap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 96, 4, 2, 32),
    (3, 50, 8, 4, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = ops.flash_decode(q, k, v, lens, group=H // KV, bk=32)
    want = ref.flash_decode_ref(q, k, v, lens, group=H // KV)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,nh,hp,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 96, 3, 16, 8, 32),
    (1, 80, 4, 32, 16, 32),     # padded last chunk
])
def test_ssd_sweep(B, S, nh, hp, N, chunk):
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bp = jax.random.normal(ks[3], (B, S, N))
    Cp = jax.random.normal(ks[4], (B, S, N))
    y, h = ops.ssd(xh, dt, A, Bp, Cp, chunk=chunk)
    y_ref, h_ref = ref.ssd_ref(xh, dt, A, Bp, Cp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_matches_model_chunked():
    """kernels.ops.ssd vs models.ssm.ssd_chunked (two implementations of the
    same math must agree)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, nh, hp, N = 2, 64, 2, 16, 8
    xh = jax.random.normal(ks[0], (B, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bp = jax.random.normal(ks[3], (B, S, N))
    Cp = jax.random.normal(ks[4], (B, S, N))
    y1, h1 = ops.ssd(xh, dt, A, Bp, Cp, chunk=16)
    y2, h2 = ssd_chunked(xh, dt, A, Bp, Cp, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
