"""JSON round-trips for every ArtifactStore payload type (load-bearing for
the pipeline cache: a lossy codec would silently corrupt warm runs), plus
unit tests of the content-addressed store itself."""
import json

import numpy as np
import pytest

from repro.core.intervals import build_profile
from repro.core.intervals_vec import as_steps
from repro.core.nugget import Nugget, create_nuggets
from repro.core.registry import BlockDef, BlockTable, Segment
from repro.core.replay import ReplayResult
from repro.core.select import (KMeansSelector, RandomSelector, Selection,
                               SystematicSelector)
from repro.pipeline import ArtifactStore, artifact_key


def small_profile():
    table = BlockTable([BlockDef("a", 10.0), BlockDef("b", 5.0),
                        BlockDef("v", 0.0, virtual=True, dyn_key="aux")],
                       [Segment((0, 1), 3)])
    steps = as_steps(n_steps=12,
                     dyn_per_step=[{"aux": float(i % 3)} for i in range(12)])
    return build_profile(table, table.step_uow() * 1.3, steps)


def roundtrip(obj, cls):
    # through an actual JSON string, as the store does — not just dicts
    return cls.from_json(json.loads(json.dumps(obj.to_json())))


@pytest.mark.parametrize("selector", [RandomSelector(n_samples=4, seed=0),
                                      SystematicSelector(n_samples=4),
                                      KMeansSelector(seed=0, max_k=4)])
def test_selection_roundtrip(selector):
    sel = selector.select(small_profile())
    sel2 = roundtrip(sel, Selection)
    assert sel2.method == sel.method
    assert sel2.interval_ids == sel.interval_ids
    np.testing.assert_allclose(sel2.weights, sel.weights)
    if sel.assignment is None:
        assert sel2.assignment is None
    else:
        np.testing.assert_array_equal(sel2.assignment, sel.assignment)


def test_nugget_roundtrip():
    prof = small_profile()
    sel = RandomSelector(n_samples=4, seed=0).select(prof)
    nugs = create_nuggets(prof, sel, warmup_intervals=1,
                          search_distance=0.3 * prof.step_uow, ckpt_every=2)
    assert nugs
    for n in nugs:
        n2 = roundtrip(n, Nugget)
        assert n2.nugget_id == n.nugget_id
        assert n2.interval_idx == n.interval_idx
        assert n2.weight == n.weight
        assert n2.plan.end == n.plan.end
        assert n2.plan.start == n.plan.start
        assert n2.plan.warmup_start == n.plan.warmup_start
        assert n2.plan.hook_fraction == n.plan.hook_fraction
        assert n2.plan.precision_loss_uow == n.plan.precision_loss_uow
        assert (n2.warmup_step, n2.start_step, n2.end_step) == \
            (n.warmup_step, n.start_step, n.end_step)
        assert (n2.uow, n2.ckpt_step) == (n.uow, n.ckpt_step)


def test_replay_result_roundtrip():
    r = ReplayResult(nugget_id=3, interval_idx=7, weight=0.25,
                     region_time_s=0.0123, steps_timed=4, warmup_steps=2,
                     uow=123.5)
    assert roundtrip(r, ReplayResult) == r


# ---------------------------------------------------------------------------
# ArtifactStore
# ---------------------------------------------------------------------------

def test_artifact_key_chains_through_upstream():
    spec = {"x": 1}
    k1 = artifact_key("selection", spec, upstream=["aaa"])
    assert k1 != artifact_key("selection", spec, upstream=["bbb"])
    assert k1 != artifact_key("selection", {"x": 2}, upstream=["aaa"])
    assert k1 != artifact_key("nuggets", spec, upstream=["aaa"])
    assert k1 == artifact_key("selection", {"x": 1}, upstream=["aaa"])


def test_artifact_key_canonicalizes_spec():
    assert artifact_key("profile", {"a": 1, "b": (2, 3)}) == \
        artifact_key("profile", {"b": [2, 3], "a": 1})


def test_store_commit_marks_complete(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = store.resolve("selection", {"selector": "random"}, ["k0"])
    assert not store.exists(art)
    store.write_json(art, "selection.json", {"method": "random"})
    # payload alone is not enough: completeness == spec.json present
    assert not store.exists(art)
    store.commit(art)
    assert store.exists(art)
    assert store.read_json(art, "selection.json") == {"method": "random"}
    assert store.keys("selection") == [art.key]
    # provenance is recorded
    doc = store.read_json(art, "spec.json")
    assert doc["upstream"] == ["k0"] and doc["kind"] == "selection"


def test_store_profile_payload_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    prof = small_profile()
    art = store.resolve("profile", {"steps": 12})
    store.write_profile(art, prof)
    store.commit(art)
    loaded = store.read_profile(art)
    assert loaded.n_intervals == prof.n_intervals
    np.testing.assert_allclose(loaded.bbv_matrix(), prof.bbv_matrix())
