"""Pipeline parallelism: GPipe over a 4-stage mesh equals sequential apply
(subprocess: needs >1 host device)."""
import json
import subprocess
import sys

import pytest

from repro.distributed.pipeline import bubble_fraction

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import gpipe

mesh = jax.make_mesh((4,), ("stage",))
S, M, B, D = 4, 6, 2, 8
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1
params = {"w": w, "b": b}
xs = jax.random.normal(jax.random.fold_in(key, 2), (M, B, D))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

# sequential reference
ref = xs
for s in range(S):
    ref = jnp.stack([stage_fn({"w": w[s], "b": b[s]}, ref[m])
                     for m in range(M)])

piped = gpipe(stage_fn, mesh)(params, xs)
err = float(jnp.max(jnp.abs(piped - ref)))
print(json.dumps({"err": err}))
"""


def test_gpipe_matches_sequential():
    out = subprocess.run([sys.executable, "-c", _CHILD],
                         capture_output=True, text=True, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    d = json.loads(out.stdout.strip().splitlines()[-1])
    assert d["err"] < 1e-5, d


def test_bubble_fraction():
    assert bubble_fraction(4, 6) == pytest.approx(3 / 9)
    assert bubble_fraction(1, 8) == 0.0
