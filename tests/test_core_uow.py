"""Unit-of-work walker + WorkMeter unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.meter import (_add64, init_meter, materialize_dyn,
                              meter_value, read_meter, read_meters,
                              tick_step)
from repro.core.registry import BlockDef, BlockTable, Segment
from repro.core.unit_of_work import jaxpr_cost, trace_cost


def test_scan_multiplies_cost():
    def body_once(x):
        return jnp.sin(x) * 2 + 1

    def scanned(x):
        def b(c, _):
            return jnp.sin(c) * 2 + 1, None
        c, _ = jax.lax.scan(b, x, None, length=7)
        return c

    c1 = trace_cost(body_once, jnp.ones(4))
    c7 = trace_cost(scanned, jnp.ones(4))
    # scan cost ≈ 7 × body + the scan op itself
    assert c7.ops >= 7 * c1.ops
    assert c7.ops <= 7 * (c1.ops + 3) + 2


def test_dot_flops():
    def f(a, b):
        return a @ b
    c = trace_cost(f, jnp.ones((8, 16)), jnp.ones((16, 4)))
    assert c.flops == pytest.approx(2 * 8 * 16 * 4)


def test_cond_counts_mean_of_branches():
    def f(x):
        return jax.lax.cond(x.sum() > 0,
                            lambda v: v * 2 + 1,
                            lambda v: v, x)
    c = trace_cost(f, jnp.ones(3))
    assert c.ops > 0


def test_while_flags_unbounded():
    def f(x):
        return jax.lax.while_loop(lambda v: v[0] < 10, lambda v: v + 1, x)
    c = trace_cost(f, jnp.zeros(2))
    assert c.unbounded_loops >= 1


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, 2**40), b=st.integers(0, 2**31 - 1))
def test_add64_two_limb(a, b):
    lo = jnp.uint32(a & 0xFFFFFFFF)
    hi = jnp.uint32(a >> 32)
    nlo, nhi = _add64(lo, hi, b)
    assert (int(nhi) << 32 | int(nlo)) == a + b


def _split64(v: int):
    return jnp.uint32(v & 0xFFFFFFFF), jnp.uint32(v >> 32)


@pytest.mark.parametrize("start,amount", [
    (2**32 - 1, 1),                  # lo rolls over exactly at the boundary
    (2**32 - 1, 2**32 - 1),          # max lo + max 32-bit amount
    (2**32, 1),                      # already past the boundary: no carry
    (2**33 - 1, 1),                  # carry with hi already nonzero
    (0, 2**32),                      # amount's own hi limb, zero low half
    (0, 2**32 + 5),                  # amount hi limb + nonzero low half
    (2**32 - 3, 2**34 + 7),          # carry AND amount hi limb together
    (0, 0),                          # degenerate no-op
])
def test_add64_carry_at_2_32_boundary(start, amount):
    lo, hi = _split64(start)
    nlo, nhi = _add64(lo, hi, amount)
    got = (int(nhi) << 32) | int(nlo)
    assert got == start + amount, (start, amount, got)


def test_meter_value_round_trips_two_limbs():
    for v in (0, 1, 2**32 - 1, 2**32, 2**32 + 1, (1 << 40) + 12345,
              (1 << 48) - 1):
        lo, hi = _split64(v)
        meter = {"uow_lo": lo, "uow_hi": hi,
                 "counts": jnp.zeros((1,), jnp.int32),
                 "steps": jnp.zeros((), jnp.int32)}
        assert meter_value(meter) == v


def test_tick_step_accumulates_across_2_32_overflow():
    """Repeated ticks whose per-step UoW pushes the two-limb counter past
    2**32 must agree with exact Python integer accumulation."""
    big = float(3_000_000_000)                        # ~0.7 * 2**32 per step
    table = BlockTable([BlockDef("a", big)], [Segment((0,), 1)])
    meter = init_meter(table)
    expect = 0
    per_step = int(round(table.step_uow()))
    for s in range(3):                                # crosses 2**32 twice
        meter = tick_step(meter, table)
        expect += per_step
        assert meter_value(meter) == expect
    assert expect > 2**32                             # overflow path exercised
    assert int(meter["uow_hi"]) >= 1
    rd = read_meter(meter)
    assert int(rd["uow"]) == expect and rd["steps"] == 3


def test_meter_accumulates_and_overflows_32bit():
    t = BlockTable([BlockDef("x", float(2**30))], [Segment((0,), 8)])
    m = init_meter(t)
    for _ in range(3):
        m = tick_step(m, t)
    assert meter_value(m) == 3 * 8 * 2**30     # > 2**32: needs limb carry
    assert int(m["counts"][0]) == 24


def test_meter_dynamic_counts():
    t = BlockTable([BlockDef("x", 5.0),
                    BlockDef("e0", 0.0, virtual=True,
                             dyn_key="expert_tokens", dyn_index=0),
                    BlockDef("e1", 0.0, virtual=True,
                             dyn_key="expert_tokens", dyn_index=1)],
                   [Segment((0,), 2)])
    m = init_meter(t)
    m = tick_step(m, t, {"expert_tokens": jnp.asarray([10, 3])})
    assert int(m["counts"][1]) == 10
    assert int(m["counts"][2]) == 3


def test_read_meters_batches_match_single_reads():
    t = BlockTable([BlockDef("x", 7.0)], [Segment((0,), 3)])
    meters = []
    m = init_meter(t)
    for _ in range(4):
        m = tick_step(m, t)
        meters.append(m)
    batch = read_meters(meters)
    assert len(batch) == 4
    for i, rd in enumerate(batch):
        single = read_meter(meters[i])
        assert int(rd["uow"]) == int(single["uow"]) == (i + 1) * 21
        assert rd["steps"] == single["steps"] == i + 1
        assert np.array_equal(rd["counts"], single["counts"])
        assert isinstance(rd["counts"], np.ndarray)
    assert read_meters([]) == []


def test_materialize_dyn_fetches_device_arrays_in_place():
    steps = [
        ("default", {"expert_tokens": jnp.asarray([4, 2]),
                     "dropped_tokens": jnp.asarray(1)}),
        ("default", None),
        ("decode", {"expert_tokens": np.asarray([9, 9])}),   # already host
    ]
    fetched = materialize_dyn(steps)
    assert fetched == 2
    for _, dyn in steps:
        if dyn:
            for v in dyn.values():
                assert isinstance(v, np.ndarray)
    assert steps[0][1]["expert_tokens"].tolist() == [4, 2]
    assert steps[0][1]["dropped_tokens"] == 1
    assert steps[2][1]["expert_tokens"].tolist() == [9, 9]
    # idempotent: second drain finds nothing device-resident
    assert materialize_dyn(steps) == 0


def test_materialize_dyn_chunked_multi_key_steps():
    """Multiple device values in one step dict across chunk boundaries all
    land (the per-assignment rebuild must not drop sibling keys)."""
    steps = [("default", {"a": jnp.asarray(i), "b": jnp.asarray(10 * i)})
             for i in range(5)]
    assert materialize_dyn(steps, chunk=3) == 10
    for i, (_, dyn) in enumerate(steps):
        assert int(dyn["a"]) == i and int(dyn["b"]) == 10 * i
        assert all(isinstance(v, np.ndarray) for v in dyn.values())


def test_hlo_analysis_histogram_and_collectives():
    from repro.core.hlo_analysis import (collective_stats, op_histogram,
                                         parse_defs)
    hlo = """
HloModule test
fused {
  %a.1 = f32[8,16] parameter(0)
  %b = f32[8,16] add(%a.1, %a.1)
  ROOT %c = f32[8,16] multiply(%b, %a.1)
}
ENTRY main {
  %p0 = f32[8,16] parameter(0)
  %ar = f32[8,16] all-reduce(%p0), replica_groups={}
  %ag = f32[32,16] all-gather(%ar), dimensions={0}
  ROOT %f = f32[8,16] fusion(%ag), kind=kLoop, calls=%fused
}
"""
    hist = op_histogram(hlo)
    assert hist["add"] == 1 and hist["all-reduce"] == 1
    sizes = parse_defs(hlo)
    assert sizes["p0"] == 8 * 16 * 4
    st_ = collective_stats(hlo)
    assert st_["all-reduce"]["count"] == 1
    assert st_["all-reduce"]["bytes"] == 8 * 16 * 4
    assert st_["all-gather"]["bytes"] == 8 * 16 * 4   # operand, not result
