import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets it in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # for the _hyp shim

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
