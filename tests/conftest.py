import os
import signal
import sys
import threading

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets it in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # for the _hyp shim

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# pytest-timeout-style per-test cap without the plugin: set
# REPRO_TEST_TIMEOUT=<seconds> (CI does) to fail any single test that
# hangs past the cap instead of stalling the whole job.
_TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT", "0"))


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    if (_TEST_TIMEOUT_S <= 0
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def _alarm(signum, frame):
        pytest.fail(f"test exceeded REPRO_TEST_TIMEOUT={_TEST_TIMEOUT_S}s",
                    pytrace=False)

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
