"""HeadLayout padding properties (hypothesis): the padded-slot layout must
keep the assigned arch's math exact for ANY (heads, kv, tp) combination."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import AttnConfig
from repro.models.attention import HeadLayout


@settings(max_examples=60, deadline=None)
@given(
    kv=st.integers(1, 16),
    group=st.integers(1, 8),
    tp=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_head_layout_invariants(kv, group, tp):
    h = kv * group
    a = AttnConfig(n_heads=h, n_kv_heads=kv, head_dim=64)
    lo = HeadLayout.make(a, tp)
    # divisibility for the mesh
    assert lo.h_pad % tp == 0
    assert lo.kv_pad % tp == 0 or lo.kv_pad == kv
    assert lo.h_pad % lo.kv_pad == 0
    # no real head lost
    assert lo.h_pad >= h and lo.kv_pad >= kv
    assert lo.kv_pad == kv * lo.repeat
    # the mask keeps exactly the real heads
    mask = lo.head_mask()
    assert mask.sum() == h
    # every real kv head serves exactly h/kv real q slots
    g_real = lo.h_pad // kv
    per_group = mask.reshape(kv, g_real).sum(axis=1)
    assert (per_group == h // kv).all()
    # q slot -> kv slot -> real kv head mapping is consistent
    s = np.arange(lo.h_pad)
    kv_slot = s // lo.group
    real_kv = kv_slot // lo.repeat
    assert (real_kv == s // g_real).all()


def test_assigned_archs_exact_layouts():
    # the five nontrivial cases on the 16-way production TP axis
    cases = {
        (40, 8): (48, 16, 2),     # llama4 / qwen2.5
        (96, 8): (96, 16, 2),     # mistral
        (64, 8): (64, 16, 2),     # internvl
        (16, 8): (16, 16, 2),     # qwen3
        (8, 4): (16, 16, 4),      # gemma3
    }
    for (h, kv), (hp, kvp, rep) in cases.items():
        lo = HeadLayout.make(AttnConfig(h, kv, 128), 16)
        assert (lo.h_pad, lo.kv_pad, lo.repeat) == (hp, kvp, rep), (h, kv, lo)
        assert lo.head_mask().sum() == h
