"""Zero-overhead marker tracking "in simulation" (paper §III-D2): block
named_scope labels must survive into the compiled HLO so the dry-run/profiler
can locate marker blocks by label (the gem5 PC-label analogue) without any
runtime hooks."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.hlo_analysis import find_scope_labels
from repro.models.model_zoo import build_model


def _hlo_for(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((2, 16), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    return jax.jit(lambda p, b: m.loss(p, b)[0]).lower(params, batch) \
        .compile().as_text()


def test_attn_and_mlp_markers_locatable():
    hlo = _hlo_for("qwen3-1.7b")
    assert find_scope_labels(hlo, "nugget_block_attn")
    assert find_scope_labels(hlo, "nugget_block_mlp")


def test_moe_marker_locatable():
    hlo = _hlo_for("olmoe-1b-7b")
    assert find_scope_labels(hlo, "nugget_block_moe")


def test_mamba_marker_locatable():
    hlo = _hlo_for("mamba2-780m")
    assert find_scope_labels(hlo, "nugget_block_mamba")
