"""Optimizer + gradient-compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         dequantize, global_norm, init_opt_state,
                         quantize_int8)


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg,
                                        jnp.asarray(0.1))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    g2 = {"a": jnp.full((10,), 1e-3)}
    clipped2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g2["a"]))


def test_master_weights_bf16_params():
    cfg = AdamWConfig(lr=1e-4, use_master=True, grad_clip=0,
                      weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    # many tiny updates that would vanish in bf16 but accumulate in master
    for _ in range(50):
        g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
        params, state, _ = adamw_update(params, g, state, cfg,
                                        jnp.asarray(1e-5))
    assert float(state.master["w"][0]) != 1.0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_quantize_int8_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6     # half-ulp of the int8 grid


def test_error_feedback_unbiased_over_time():
    """EF compression: the *accumulated* applied signal tracks the true
    accumulated gradient (bias shrinks), though each step is lossy."""
    from repro.optim.grad_compress import compress_leaf
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    ef = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    for _ in range(60):
        q, s, ef = compress_leaf(g_true, ef)
        applied = applied + dequantize(q, s)
    # mean applied per step ≈ g_true
    np.testing.assert_allclose(np.asarray(applied) / 60, np.asarray(g_true),
                               atol=2e-2)


def test_compressed_psum_matches_sum_shardmap():
    """int8 EF psum under shard_map on 1 device == plain sum (n=1)."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.grad_compress import compressed_psum, init_error_feedback

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    g = {"w": jnp.linspace(-1, 1, 32)}
    ef = init_error_feedback(g)

    def f(g, ef):
        return compressed_psum(g, ef, "dp")

    out, new_ef = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                            out_specs=(P(), P()))(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=1e-2)
