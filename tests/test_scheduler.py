"""Unit tests for the concurrent DAG scheduler (``pipeline/scheduler.py``)
and the artifact store's single-flight concurrency contract."""
import json
import os
import threading
import time

import pytest

from repro import obs
from repro.pipeline import ArtifactStore, run_dag
from repro.pipeline.stages import Stage


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.configure(trace=False, reset_metrics=True)
    yield
    obs.configure(trace=False, reset_metrics=True)


DIAMOND_ORDER = ["a", "b", "c", "d"]
DIAMOND_DEPS = {"a": [], "b": ["a"], "c": ["a"], "d": ["b", "c"]}


def _record_runner(log, lock=None, delay=0.0):
    lock = lock or threading.Lock()

    def run(name):
        if delay:
            time.sleep(delay)
        with lock:
            log.append(name)
    return run


# -- run_dag ------------------------------------------------------------
def test_serial_runs_in_declaration_order():
    log = []
    run_dag(DIAMOND_ORDER, DIAMOND_DEPS, _record_runner(log), max_workers=0)
    assert log == ["a", "b", "c", "d"]


def test_serial_declaration_order_breaks_ties_not_deps():
    # declared out of dependency order: the scheduler still runs deps first,
    # ties broken by declaration position
    log = []
    run_dag(["d", "c", "b", "a"], DIAMOND_DEPS, _record_runner(log),
            max_workers=1)
    assert log == ["a", "c", "b", "d"]


@pytest.mark.parametrize("workers", [2, 4])
def test_parallel_respects_dependencies(workers):
    log = []
    run_dag(DIAMOND_ORDER, DIAMOND_DEPS, _record_runner(log, delay=0.005),
            max_workers=workers)
    assert sorted(log) == ["a", "b", "c", "d"]
    pos = {n: i for i, n in enumerate(log)}
    assert pos["a"] < pos["b"] and pos["a"] < pos["c"]
    assert pos["d"] == 3


def test_parallel_overlaps_independent_nodes():
    """Two independent nodes must genuinely run concurrently: each blocks
    until the other has started, so serial execution would deadlock."""
    started = {"x": threading.Event(), "y": threading.Event()}
    other = {"x": "y", "y": "x"}

    def run(name):
        started[name].set()
        assert started[other[name]].wait(timeout=10.0), \
            f"{name} never overlapped with {other[name]}"

    run_dag(["x", "y"], {"x": [], "y": []}, run, max_workers=2)


@pytest.mark.parametrize("workers", [0, 4])
def test_cycle_raises(workers):
    with pytest.raises(RuntimeError, match="cycle"):
        run_dag(["a", "b"], {"a": ["b"], "b": ["a"]},
                lambda n: None, max_workers=workers)


def test_unknown_dependency_raises():
    with pytest.raises(ValueError, match="unknown"):
        run_dag(["a"], {"a": ["ghost"]}, lambda n: None)


def test_duplicate_node_raises():
    with pytest.raises(ValueError, match="duplicate"):
        run_dag(["a", "a"], {"a": []}, lambda n: None)


@pytest.mark.parametrize("workers", [0, 3])
def test_node_error_propagates_and_blocks_downstream(workers):
    log = []

    def run(name):
        if name == "b":
            raise RuntimeError("stage b exploded")
        log.append(name)

    with pytest.raises(RuntimeError, match="stage b exploded"):
        run_dag(["a", "b", "c"], {"a": [], "b": ["a"], "c": ["b"]},
                run, max_workers=workers)
    assert "c" not in log          # downstream of the failure never ran


def test_workers_tag_spans():
    t = obs.configure(trace=True)
    run_dag(DIAMOND_ORDER, DIAMOND_DEPS,
            lambda name: obs.event(f"node.{name}"),
            max_workers=2, thread_name_prefix="sched")
    evs = t.events()
    workers = {e["args"].get("worker") for e in evs
               if e["name"].startswith("node.")}
    assert workers and all(w and w.startswith("sched") for w in workers)
    # chrome export names the worker threads via thread_name metadata
    meta = [r for r in obs.chrome_trace(evs)["traceEvents"]
            if r.get("ph") == "M" and r.get("name") == "thread_name"]
    named = {r["args"]["name"] for r in meta}
    assert workers <= named


# -- store single-flight ------------------------------------------------
class _CountingStage(Stage):
    """Minimal stage: spec is fixed, compute counts its invocations."""

    kind = "validation"            # any registered kind works
    name = "counting"

    def __init__(self):
        self.computes = 0
        self._lock = threading.Lock()

    def spec(self, ctx):
        return {"fixed": 1}

    def compute(self, ctx):
        with self._lock:
            self.computes += 1
        time.sleep(0.02)           # widen the race window
        return {"value": 42}

    def save(self, store, art, payload):
        store.write_json(art, "payload.json", payload)

    def load(self, store, art):
        return store.read_json(art, "payload.json")


class _DummyCtx:
    def __init__(self, store):
        self.store = store
        self.records = []
        self._lock = threading.Lock()

    def record(self, stage, art, payload, hit, wall_s):
        with self._lock:
            self.records.append((stage.name, art.key, payload, hit))


def test_single_flight_computes_shared_key_once(tmp_path):
    store = ArtifactStore(str(tmp_path))
    stage = _CountingStage()
    ctx = _DummyCtx(store)
    n = 8
    barrier = threading.Barrier(n)
    errors = []

    def racer():
        try:
            barrier.wait(timeout=10.0)
            stage.run(ctx)
        except Exception as e:      # pragma: no cover - fail loudly below
            errors.append(e)

    threads = [threading.Thread(target=racer) for _ in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30.0)
    assert not errors
    assert stage.computes == 1, "shared key computed more than once"
    assert len(ctx.records) == n
    keys = {k for _, k, _, _ in ctx.records}
    assert len(keys) == 1
    payloads = [p for _, _, p, _ in ctx.records]
    assert all(p == {"value": 42} for p in payloads)
    hits = [h for _, _, _, h in ctx.records]
    assert hits.count(False) == 1 and hits.count(True) == n - 1


def test_concurrent_commit_is_idempotent(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = store.resolve("validation", {"x": 1})
    store.write_json(art, "payload.json", {"ok": True})
    n = 6
    barrier = threading.Barrier(n)

    def committer():
        barrier.wait(timeout=10.0)
        store.commit(art)

    threads = [threading.Thread(target=committer) for _ in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30.0)
    marker = os.path.join(art.path, "spec.json")
    with open(marker) as f:
        doc = json.load(f)
    assert doc["key"] == art.key
    # exactly one commit actually wrote; the rest deduped
    assert obs.metrics().snapshot()["store.put"]["value"] == 1
    assert not [f for f in os.listdir(art.path) if f.endswith(".tmp")]
    assert store.exists(art)


def test_single_flight_reentrant_for_commit(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = store.resolve("validation", {"y": 2})
    with store.single_flight(art.key):
        store.write_json(art, "payload.json", {})
        store.commit(art)          # must not deadlock on the same key lock
    assert store.exists(art)


# -- failure paths ------------------------------------------------------
from repro.faults import (  # noqa: E402
    InjectedFault, RetryPolicy, StageTimeout, WorkerKilled,
)

FAST_RETRY = RetryPolicy(max_attempts=3, backoff_s=0.001, jitter_frac=0.0)


def _fail_n_times(n, exc_factory, log=None):
    """run(name) that raises the first ``n`` calls per node, then passes."""
    calls = {}
    lock = threading.Lock()

    def run(name):
        with lock:
            calls[name] = calls.get(name, 0) + 1
            k = calls[name]
        if log is not None:
            with lock:
                log.append((name, k))
        if k <= n:
            raise exc_factory(name)
    run.calls = calls
    return run


@pytest.mark.parametrize("workers", [0, 2])
def test_transient_failure_retries_then_succeeds(workers):
    run = _fail_n_times(1, lambda n: InjectedFault(f"{n} flaked"))
    stats = run_dag(["a", "b"], {"a": [], "b": ["a"]}, run,
                    max_workers=workers, retry=FAST_RETRY)
    assert run.calls == {"a": 2, "b": 2}
    assert stats["retries"] == 2
    assert stats["timeouts"] == 0 and not stats["fallback_serial"]
    assert obs.metrics().snapshot()["pipeline.retries"]["value"] == 2


@pytest.mark.parametrize("workers", [0, 2])
def test_transient_exhausts_attempts_then_raises(workers):
    run = _fail_n_times(99, lambda n: InjectedFault(f"{n} flaked"))
    with pytest.raises(InjectedFault):
        run_dag(["a"], {"a": []}, run, max_workers=workers, retry=FAST_RETRY)
    assert run.calls == {"a": FAST_RETRY.max_attempts}


@pytest.mark.parametrize("workers", [0, 2])
def test_fatal_failure_not_retried(workers):
    run = _fail_n_times(1, lambda n: ValueError(f"{n} is buggy"))
    with pytest.raises(ValueError):
        run_dag(["a"], {"a": []}, run, max_workers=workers, retry=FAST_RETRY)
    assert run.calls == {"a": 1}, "fatal errors must surface on attempt 1"


@pytest.mark.parametrize("workers", [0, 2])
def test_timeout_fires_mid_stage_then_retry_succeeds(workers):
    """Attempt 1 stalls past the wall-clock budget -> StageTimeout is
    transient -> attempt 2 runs fast and the node completes."""
    calls = {}
    lock = threading.Lock()

    def run(name):
        with lock:
            calls[name] = calls.get(name, 0) + 1
            k = calls[name]
        if k == 1:
            time.sleep(5.0)        # stalls well past the 0.1s budget

    retry = RetryPolicy(max_attempts=3, backoff_s=0.001, jitter_frac=0.0,
                        timeout_s=0.1)
    stats = run_dag(["a"], {"a": []}, run, max_workers=workers, retry=retry)
    assert calls == {"a": 2}
    assert stats["timeouts"] == 1 and stats["retries"] == 1
    assert obs.metrics().snapshot()["pipeline.timeouts"]["value"] == 1


def test_timeout_exhausts_attempts_raises_stage_timeout():
    retry = RetryPolicy(max_attempts=2, backoff_s=0.001, jitter_frac=0.0,
                        timeout_s=0.05)
    with pytest.raises(StageTimeout, match="wall-clock"):
        run_dag(["a"], {"a": []}, lambda n: time.sleep(5.0), retry=retry)


def test_worker_kill_requeues_without_fallback():
    """One worker death: the node is rescheduled on the pool and the run
    completes with no serial downgrade."""
    run = _fail_n_times(1, lambda n: WorkerKilled(f"{n} worker died"))
    stats = run_dag(["a", "b"], {"a": [], "b": ["a"]},
                    lambda n: run(n) if n == "b" else None, max_workers=2)
    assert stats["worker_failures"] == 1
    assert not stats["fallback_serial"]
    assert run.calls == {"b": 2}


def test_repeated_worker_kills_degrade_to_serial():
    """serial_fallback_after deaths drain the pool and the remaining
    graph finishes on the caller's thread."""
    kills = _fail_n_times(2, lambda n: WorkerKilled(f"{n} worker died"))
    done = []
    lock = threading.Lock()
    caller = threading.current_thread().name

    def run(name):
        if name == "b":
            kills(name)
        with lock:
            done.append((name, threading.current_thread().name))

    stats = run_dag(["a", "b", "c"], {"a": [], "b": ["a"], "c": ["b"]},
                    run, max_workers=2, serial_fallback_after=2)
    assert stats["worker_failures"] == 2
    assert stats["fallback_serial"] is True
    assert sorted(n for n, _ in done) == ["a", "b", "c"]
    # the post-degrade tail ran on the calling thread, not the pool
    tail_threads = {t for n, t in done if n in ("b", "c")}
    assert tail_threads == {caller}
    assert obs.metrics().snapshot()["scheduler.fallback_serial"]["value"] == 1


def test_worker_kill_on_caller_thread_retries_like_transient():
    # serial mode has no worker to lose: a kill is just a transient error
    run = _fail_n_times(1, lambda n: WorkerKilled(f"{n} died"))
    stats = run_dag(["a"], {"a": []}, run, max_workers=0, retry=FAST_RETRY)
    assert run.calls == {"a": 2}
    assert stats["retries"] == 1 and stats["worker_failures"] == 0


class _FlakyOnceStage(_CountingStage):
    """Compute fails transiently exactly once (globally), then succeeds."""

    def __init__(self):
        super().__init__()
        self.failures = 0

    def compute(self, ctx):
        with self._lock:
            first = self.computes == 0 and self.failures == 0
            if first:
                self.failures += 1
        if first:
            raise InjectedFault("first compute flaked")
        return super().compute(ctx)


def test_single_flight_loser_sees_winners_retried_result(tmp_path):
    """Two nodes race the same artifact key; the first compute fails
    transiently.  The retry machinery must leave BOTH records holding the
    winner's good payload — never the failed attempt."""
    store = ArtifactStore(str(tmp_path))
    stage = _FlakyOnceStage()
    ctx = _DummyCtx(store)
    stats = run_dag(["n1", "n2"], {"n1": [], "n2": []},
                    lambda name: stage.run(ctx),
                    max_workers=2, retry=FAST_RETRY)
    assert stats["retries"] == 1
    assert stage.failures == 1 and stage.computes == 1
    assert len(ctx.records) == 2
    assert all(p == {"value": 42} for _, _, p, _ in ctx.records)
    assert len({k for _, k, _, _ in ctx.records}) == 1
