"""Checkpointer: atomic commit, checksum, keep-N GC, async, exact resume,
elastic (resharded) restore via template."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced
from repro.train import Trainer


def _tree(key, scale=1.0):
    return {"a": {"w": scale * jax.random.normal(key, (8, 4))},
            "b": jnp.arange(5, dtype=jnp.int32),
            "step": jnp.asarray(3)}


def test_roundtrip(tmp_path, rng_key):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree(rng_key)
    ck.save(5, t)
    restored, extra = ck.restore(t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.latest_step() == 5


def test_keep_n_gc(tmp_path, rng_key):
    ck = Checkpointer(str(tmp_path), keep_n=2, async_save=False)
    t = _tree(rng_key)
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path, rng_key):
    ck = Checkpointer(str(tmp_path), async_save=True)
    t = _tree(rng_key)
    ck.save(7, t, extra={"note": "x"})
    ck.wait()
    restored, extra = ck.restore(t)
    assert extra == {"note": "x"}


def test_corruption_detected(tmp_path, rng_key):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree(rng_key)
    ck.save(1, t)
    # corrupt the payload
    p = os.path.join(str(tmp_path), "step_00000001", "arrays_p0.npz")
    data = open(p, "rb").read()
    with open(p, "wb") as f:
        f.write(data[:100] + b"\x00" * 50 + data[150:])
    with pytest.raises(Exception):
        ck.restore(t)


def test_partial_write_never_committed(tmp_path, rng_key):
    """A .tmp- dir (simulated crash mid-write) must be invisible to LATEST."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree(rng_key)
    ck.save(1, t)
    os.makedirs(os.path.join(str(tmp_path), ".tmp-step_00000002-0"))
    assert ck.latest_step() == 1


@pytest.mark.slow
def test_resume_matches_uninterrupted(tmp_path, rng_key):
    """checkpoint/restart at step 6 must reproduce the uninterrupted run
    exactly (stateless data cursor + saved rng/opt state)."""
    cfg = reduced(get_config("qwen3-1.7b"))
    t1 = Trainer(cfg, seq_len=16, batch=2, instrument=False,
                 ckpt_dir=str(tmp_path / "a"), ckpt_every=6, donate=False)
    s_full = t1.run(10)

    t2 = Trainer(cfg, seq_len=16, batch=2, instrument=False,
                 ckpt_dir=str(tmp_path / "a"), ckpt_every=6, donate=False)
    s_resumed = t2.run(10)     # restores step 6, runs 6..10
    assert int(s_resumed.step) == 10
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_elastic_restore_with_dtype_cast(tmp_path, rng_key):
    """Restore into a template with different leaf dtype (elastic/reshard
    path casts + re-device_puts)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = {"w": jnp.ones((4, 4), jnp.float32)}
    ck.save(1, t)
    template = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    restored, _ = ck.restore(template)
    assert restored["w"].dtype == jnp.bfloat16
