"""End-to-end behaviour of the artifact pipeline (ISSUE 7 tentpole):
cold run computes every stage, warm run hits the cache on every stage,
and changing only the selector re-runs selection + downstream while the
profile and baseline artifacts are reused."""
import dataclasses

import pytest

from repro.pipeline import Pipeline, PipelineConfig

CFG = PipelineConfig(arch="olmoe-1b-7b", platforms=("f32",),
                     selector="random",
                     selector_args={"n_samples": 3, "seed": 0},
                     steps=8, seq_len=16, batch=2, interval_steps=2.0,
                     seed=0)

STAGE_NAMES = ["profile", "select", "mark", "baseline@f32", "replay@f32",
               "validate"]


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    return str(tmp_path_factory.mktemp("artifact-store"))


@pytest.fixture(scope="module")
def cold(store):
    return Pipeline(CFG, store).run()


def hits(manifest):
    return {s["stage"]: s["cache_hit"] for s in manifest["stages"]}


def test_cold_run_computes_every_stage(cold):
    assert [s["stage"] for s in cold["stages"]] == STAGE_NAMES
    assert cold["cache_hits"] == 0
    assert cold["cache_misses"] == len(STAGE_NAMES)
    m = cold["metrics"]
    assert "f32" in m["platforms"]
    assert m["platforms"]["f32"]["actual_s"] > 0
    assert m["platforms"]["f32"]["predicted_s"] > 0
    assert len(m["nugget_variability"]) == 3
    # single platform: no speedup pairs, but consistency is still populated
    assert m["speedup_errors"] == []
    assert all(s["wall_s"] >= 0 for s in cold["stages"])


def test_warm_run_hits_every_stage(store, cold):
    warm = Pipeline(CFG, store).run()
    assert all(hits(warm).values()), hits(warm)
    # identical inputs -> identical content addresses
    assert [s["key"] for s in warm["stages"]] == \
        [s["key"] for s in cold["stages"]]
    # the cached validation payload round-trips losslessly
    assert warm["metrics"] == cold["metrics"]


def test_selector_change_reuses_profile_and_baseline(store, cold):
    cfg = dataclasses.replace(CFG, selector="systematic",
                              selector_args={"n_samples": 3})
    m = Pipeline(cfg, store).run()
    h = hits(m)
    assert h["profile"] and h["baseline@f32"], h
    assert not h["select"] and not h["mark"], h
    assert not h["replay@f32"] and not h["validate"], h
    # profile artifact is the same object, selection is a new one
    keys = {s["stage"]: s["key"] for s in m["stages"]}
    cold_keys = {s["stage"]: s["key"] for s in cold["stages"]}
    assert keys["profile"] == cold_keys["profile"]
    assert keys["select"] != cold_keys["select"]


def test_interval_change_invalidates_profile(store, cold):
    cfg = dataclasses.replace(CFG, interval_steps=3.0)
    m = Pipeline(cfg, store).run()
    h = hits(m)
    assert not h["profile"], h
    # baselines do not depend on the interval size
    assert h["baseline@f32"], h
