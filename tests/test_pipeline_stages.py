"""End-to-end behaviour of the artifact pipeline (ISSUE 7 tentpole):
cold run computes every stage, warm run hits the cache on every stage,
and changing only the selector re-runs selection + downstream while the
profile and baseline artifacts are reused.  The concurrent DAG scheduler
(ISSUE 9) must reproduce the serial run exactly: identical stage keys,
bit-for-bit identical profile payload, identical selection/nugget JSON."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.profile_store import load_profile
from repro.pipeline import Pipeline, PipelineConfig

CFG = PipelineConfig(arch="olmoe-1b-7b", platforms=("f32",),
                     selector="random",
                     selector_args={"n_samples": 3, "seed": 0},
                     steps=8, seq_len=16, batch=2, interval_steps=2.0,
                     seed=0)

STAGE_NAMES = ["profile", "select", "mark", "baseline@f32", "replay@f32",
               "validate"]


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    return str(tmp_path_factory.mktemp("artifact-store"))


@pytest.fixture(scope="module")
def cold(store):
    return Pipeline(CFG, store).run()


def hits(manifest):
    return {s["stage"]: s["cache_hit"] for s in manifest["stages"]}


def test_cold_run_computes_every_stage(cold):
    assert [s["stage"] for s in cold["stages"]] == STAGE_NAMES
    assert cold["cache_hits"] == 0
    assert cold["cache_misses"] == len(STAGE_NAMES)
    m = cold["metrics"]
    assert "f32" in m["platforms"]
    assert m["platforms"]["f32"]["actual_s"] > 0
    assert m["platforms"]["f32"]["predicted_s"] > 0
    assert len(m["nugget_variability"]) == 3
    # single platform: no speedup pairs, but consistency is still populated
    assert m["speedup_errors"] == []
    assert all(s["wall_s"] >= 0 for s in cold["stages"])


def test_warm_run_hits_every_stage(store, cold):
    warm = Pipeline(CFG, store).run()
    assert all(hits(warm).values()), hits(warm)
    # identical inputs -> identical content addresses
    assert [s["key"] for s in warm["stages"]] == \
        [s["key"] for s in cold["stages"]]
    # the cached validation payload round-trips losslessly
    assert warm["metrics"] == cold["metrics"]


def test_selector_change_reuses_profile_and_baseline(store, cold):
    cfg = dataclasses.replace(CFG, selector="systematic",
                              selector_args={"n_samples": 3})
    m = Pipeline(cfg, store).run()
    h = hits(m)
    assert h["profile"] and h["baseline@f32"], h
    assert not h["select"] and not h["mark"], h
    assert not h["replay@f32"] and not h["validate"], h
    # profile artifact is the same object, selection is a new one
    keys = {s["stage"]: s["key"] for s in m["stages"]}
    cold_keys = {s["stage"]: s["key"] for s in cold["stages"]}
    assert keys["profile"] == cold_keys["profile"]
    assert keys["select"] != cold_keys["select"]


def test_interval_change_invalidates_profile(store, cold):
    cfg = dataclasses.replace(CFG, interval_steps=3.0)
    m = Pipeline(cfg, store).run()
    h = hits(m)
    assert not h["profile"], h
    # baselines do not depend on the interval size
    assert h["baseline@f32"], h


def test_store_counters_cold_misses_warm_hits(store, cold):
    """ArtifactStore cache accounting (ISSUE 8): a cold run is all misses
    (every artifact is written), a warm run is all hits (nothing written)."""
    sc = cold["obs"]["store_counters"]
    assert sc["miss"] == len(STAGE_NAMES) and sc["hit"] == 0, sc
    assert sc["put_bytes"] > 0
    warm = Pipeline(CFG, store).run()
    sw = warm["obs"]["store_counters"]
    assert sw["hit"] == len(STAGE_NAMES) and sw["miss"] == 0, sw
    assert sw["put_bytes"] == 0              # pure cache hits write nothing


def test_manifest_embeds_metrics_snapshot(store, cold):
    ob = cold["obs"]
    assert "metrics" in ob and isinstance(ob["metrics"], dict)
    # the snapshot is plain JSON (the manifest is dumped as-is)
    import json
    json.dumps(ob["metrics"])
    snap = ob["metrics"]
    assert snap["store.miss"]["value"] >= len(STAGE_NAMES)
    assert "pipeline.stage_s.profile" in snap


def test_parallel_run_is_deterministic(tmp_path, cold):
    """A cold ``workers=4`` run against a fresh store must reproduce the
    serial run exactly: identical input-addressed stage keys, bit-for-bit
    identical profile payload, identical selection/nugget JSON, and
    replay results identical up to the wall-clock timing fields."""
    cfg = dataclasses.replace(CFG, workers=4)
    par = Pipeline(cfg, str(tmp_path)).run()
    assert par["workers"] == 4
    assert par["cache_misses"] == len(STAGE_NAMES)
    # manifest reports stages in declaration order regardless of the
    # order worker threads finished them
    assert [s["stage"] for s in par["stages"]] == STAGE_NAMES
    paths = {s["stage"]: s["path"] for s in par["stages"]}
    cold_paths = {s["stage"]: s["path"] for s in cold["stages"]}

    # identical content addresses on every stage
    assert {s["stage"]: s["key"] for s in par["stages"]} == \
        {s["stage"]: s["key"] for s in cold["stages"]}

    # profile payload is bit-for-bit identical (sharded analysis merge)
    ps = load_profile(os.path.join(cold_paths["profile"], "profile"))
    pp = load_profile(os.path.join(paths["profile"], "profile"))
    assert len(ps.intervals) == len(pp.intervals)
    np.testing.assert_array_equal(ps.bbv_matrix(), pp.bbv_matrix())
    for a, b in zip(ps.intervals, pp.intervals):
        assert a.start_uow == b.start_uow and a.end_uow == b.end_uow
        assert a.end_marker == b.end_marker
        np.testing.assert_array_equal(a.stamps, b.stamps)
        np.testing.assert_array_equal(a.hits_at_stamp, b.hits_at_stamp)

    # selection + nugget JSON byte-identical
    for stage, fname in (("select", "selection.json"),
                         ("mark", "nuggets.json")):
        with open(os.path.join(cold_paths[stage], fname), "rb") as f:
            serial_doc = f.read()
        with open(os.path.join(paths[stage], fname), "rb") as f:
            assert f.read() == serial_doc, f"{stage} payload diverged"

    # replay results identical up to wall-clock timings
    def strip_times(path):
        with open(os.path.join(path, "replay.json")) as f:
            doc = json.load(f)
        for r in doc["results"]:
            for k in list(r):
                if k.endswith("_s"):        # region_time_s etc.
                    del r[k]
        return doc

    assert strip_times(paths["replay@f32"]) == \
        strip_times(cold_paths["replay@f32"])


def test_traced_warm_run_emits_one_span_per_stage(store, cold):
    """With tracing on, a pipeline run produces a ``stage.<name>`` span per
    stage (cache-hit attribute set) inside a ``pipeline.run`` root span,
    and the buffer exports as a valid Chrome trace."""
    from repro import obs
    tracer = obs.configure(trace=True)
    try:
        m = Pipeline(CFG, store).run()
    finally:
        obs.configure(trace=False)
    assert m["obs"]["traced"]
    spans = [e for e in tracer.events() if e["ph"] == "X"]
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)
    for name in STAGE_NAMES:
        (ev,) = by_name[f"stage.{name}"]
        assert ev["args"]["cache_hit"] is True
        assert ev["args"]["key"]          # artifact digest travels on the span
    assert len(by_name["pipeline.run"]) == 1
    doc = tracer.chrome_trace()
    assert {e["ph"] for e in doc["traceEvents"]} <= {"M", "X", "i"}
