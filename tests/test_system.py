"""End-to-end behaviour of the paper's system (Fig. 1 pipeline): instrumented
training -> interval profile -> selection -> nugget creation -> native replay
-> validation, plus cross-platform consistency and the profile store."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (KMeansSelector, RandomSelector, ReplayEngine,
                        consistency_report, create_nuggets, load_nuggets,
                        load_profile, measure_full_run, nugget_variability,
                        predict_total_time, prediction_error, save_nuggets,
                        save_profile, signature_divergence,
                        speedup_error_matrix, PlatformResult)
from repro.train import Trainer

N_STEPS = 30


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    d = tmp_path_factory.mktemp("ck")
    cfg = reduced(get_config("olmoe-1b-7b"))
    tr = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=str(d), ckpt_every=10,
                 interval_steps=2.5, seed=0)
    tr.run(N_STEPS)
    return tr


@pytest.mark.slow
def test_pipeline_end_to_end(trained, tmp_path):
    tr = trained
    prof = tr.profile()
    assert prof.n_steps == N_STEPS
    assert prof.n_intervals >= 5

    sel = KMeansSelector(seed=0).select(prof)
    nugs = create_nuggets(prof, sel, warmup_intervals=1, ckpt_every=10)
    assert len(nugs) == len(sel.interval_ids)

    runner = tr.make_runner()
    eng = ReplayEngine(runner, prof)
    results = eng.replay_all(nugs)
    pred = predict_total_time(prof, results)
    actual = measure_full_run(runner, N_STEPS)
    err = abs(prediction_error(pred, actual))
    # on-platform prediction should be in the paper's plausible band
    assert err < 0.5, f"prediction error {err:.2%}"

    # artifact round-trips
    pdir = str(tmp_path / "prof")
    save_profile(pdir, prof)
    prof2 = load_profile(pdir)
    assert prof2.n_intervals == prof.n_intervals
    np.testing.assert_allclose(prof2.bbv_matrix(), prof.bbv_matrix())
    npath = str(tmp_path / "nuggets.json")
    save_nuggets(npath, nugs, sel)
    nugs2, sel2 = load_nuggets(npath)
    assert [n.interval_idx for n in nugs2] == [n.interval_idx for n in nugs]


def test_moe_phases_visible_in_bbvs(trained):
    """The phased corpus shifts expert routing; interval BBVs must reflect
    it (the data-dependent signature entries carry real signal)."""
    prof = trained.profile()
    x = prof.bbv_matrix()
    virt = prof.table.virtual_ids()
    v = x[:, virt[:-1]]                        # expert_tok_* columns
    v = v / np.maximum(v.sum(1, keepdims=True), 1)
    spread = v.max(0) - v.min(0)
    assert spread.max() > 0.02                 # routing mix moves over phases


def test_meter_matches_host_builder(trained):
    """Device WorkMeter (in-jit hooks) agrees with the host-side stream."""
    from repro.core.meter import read_meter
    tr = trained
    state = tr.init_state()
    batch = tr._device_batch(0)
    state, _, _ = tr._step_fn(state, batch)
    m = read_meter(state.meter)
    assert m["steps"] == 1
    table = tr.table
    want = table.step_counts()
    got = m["counts"]
    nv = [i for i, b in enumerate(table.blocks) if not b.virtual]
    np.testing.assert_array_equal(got[nv], want[nv])
    assert int(m["uow"]) == int(round(table.step_uow()))


@pytest.mark.slow
def test_cross_platform_consistency(trained):
    """Two 'platforms' (instrumented vs plain step programs) — §V-A
    consistency analysis machinery."""
    tr = trained
    prof = tr.profile()
    sel = RandomSelector(n_samples=6, seed=1).select(prof)
    nugs = create_nuggets(prof, sel, warmup_intervals=1, ckpt_every=10)
    results_by = {}
    plats = []
    for name, instrument in (("instrumented", True), ("plain", False)):
        runner = tr.make_runner(instrument=instrument)
        eng = ReplayEngine(runner, prof)
        res = eng.replay_all(nugs)
        results_by[name] = res
        pred = predict_total_time(prof, res)
        actual = measure_full_run(runner, N_STEPS)
        plats.append(PlatformResult(name, pred, actual))
    rep = consistency_report(plats)
    assert set(rep) >= {"mean_abs_error", "error_spread", "consistent"}
    sp = speedup_error_matrix(plats)
    assert len(sp) == 1 and "abs_speedup_error" in sp[0]
    var = nugget_variability(results_by)
    assert len(var) == len(nugs)


def test_signature_divergence_same_platform_is_zero(trained):
    prof = trained.profile()
    rep = signature_divergence(prof, prof)
    assert rep["max_rel_divergence"] == 0.0


def test_watchdog_tracks_steps(trained):
    rep = trained.watchdog_report()
    assert len(rep.step_times) == N_STEPS
    assert 0 <= rep.straggler_fraction() <= 1


def test_unit_of_work_binary_independence():
    """The paper's portability claim, adapted: the unit of work is measured
    on the portable IR *before* backend compilation, so it is (a) exactly
    deterministic for a fixed program, and therefore identical across
    backends/XLA option sets/donation (which never see the jaxpr), and (b)
    only mildly perturbed by dtype changes (casts appear in the IR — the
    paper's LSMS fp-precision caveat, §IV-A2)."""
    from repro.configs import ShapeConfig
    from repro.core import build_block_table
    from repro.models.model_zoo import build_model

    cfg32 = reduced(get_config("qwen3-1.7b"))
    shape = ShapeConfig("t", "train", 32, 2)
    # (a) exact determinism of the portable measurement
    a = build_block_table(build_model(cfg32), shape)
    b = build_block_table(build_model(cfg32), shape)
    assert a.names == b.names
    np.testing.assert_array_equal(a.costs(), b.costs())
    # (b) dtype platform: same block structure, bounded IR perturbation
    cfg16 = dataclasses.replace(cfg32, compute_dtype="bfloat16",
                                param_dtype="bfloat16")
    t16 = build_block_table(build_model(cfg16), shape)
    assert t16.names == a.names
    rel = np.abs(t16.costs() - a.costs()) / np.maximum(a.costs(), 1)
    assert rel.max() < 0.25, rel
