"""Data pipeline: determinism (replay-critical), phases, packing, prefetch."""
import numpy as np
import pytest

from repro.data import (PrefetchLoader, SyntheticCorpus, default_schedule,
                        pack_documents, packing_efficiency)


def test_batch_at_deterministic():
    c1 = SyntheticCorpus(1000, 32, 4, seed=3)
    c2 = SyntheticCorpus(1000, 32, 4, seed=3)
    for s in (0, 5, 17):
        b1, b2 = c1.batch_at(s), c2.batch_at(s)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    c = SyntheticCorpus(1000, 32, 2, seed=0)
    b = c.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_phases_change_token_distribution():
    c = SyntheticCorpus(10000, 256, 8, seed=0)
    sched = c.schedule
    m0 = sched.mix_at(0)
    m1 = sched.mix_at(30)
    assert m0 != m1
    b0 = c.batch_at(0)["tokens"].mean()
    b1 = c.batch_at(30)["tokens"].mean()
    assert abs(float(b0) - float(b1)) > 1.0   # different vocab bands


def test_seed_changes_stream():
    a = SyntheticCorpus(1000, 32, 2, seed=0).batch_at(0)["tokens"]
    b = SyntheticCorpus(1000, 32, 2, seed=1).batch_at(0)["tokens"]
    assert (a != b).any()


def test_packing():
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 100, size=rng.integers(3, 40)) for _ in range(30)]
    packed = pack_documents(docs, seq_len=64)
    assert packed["tokens"].shape == packed["segment_ids"].shape
    # every doc's tokens present
    total = sum(min(len(d), 64) for d in docs)
    assert int((packed["segment_ids"] > 0).sum()) == total
    assert packing_efficiency(packed) > 0.5
    # positions restart per segment
    seg, pos = packed["segment_ids"], packed["positions"]
    for r in range(seg.shape[0]):
        for j in range(1, seg.shape[1]):
            if seg[r, j] != 0 and seg[r, j] == seg[r, j - 1]:
                assert pos[r, j] == pos[r, j - 1] + 1


def test_prefetch_loader_in_order_and_reset():
    c = SyntheticCorpus(1000, 16, 2, seed=0)
    ld = PrefetchLoader(c.batch_at, depth=2)
    try:
        b0 = ld.get(0)
        b1 = ld.get(1)
        np.testing.assert_array_equal(b0["tokens"], c.batch_at(0)["tokens"])
        np.testing.assert_array_equal(b1["tokens"], c.batch_at(1)["tokens"])
        ld.reset(10)
        b10 = ld.get(10)
        np.testing.assert_array_equal(b10["tokens"], c.batch_at(10)["tokens"])
    finally:
        ld.stop()
