"""Unit tests for the ``repro.obs`` tracing + metrics + logging layer."""
import json
import logging
import threading

import pytest

from repro import obs
from repro.obs import (MetricsRegistry, Tracer, chrome_trace, read_events,
                       span_summary)
from repro.obs.log import KVFormatter, resolve_level, setup
from repro.launch.obs import main as obs_cli


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test gets a disabled tracer and a fresh metrics registry."""
    obs.configure(trace=False, reset_metrics=True)
    yield
    obs.configure(trace=False, reset_metrics=True)


# -- tracer -------------------------------------------------------------
def test_disabled_span_is_shared_noop():
    assert not obs.enabled()
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is s2 is obs.NULL_SPAN
    with s1 as sp:
        sp.set(anything=True)
        sp.event("ignored")
    assert obs.tracer().events() == []


def test_spans_nest_and_record_duration():
    t = obs.configure(trace=True)
    with t.span("outer", stage="profile") as outer:
        assert t.depth() == 1
        with t.span("inner"):
            assert t.depth() == 2
        outer.event("milestone", n=3)
    assert t.depth() == 0
    evs = t.events()
    names = [e["name"] for e in evs]
    # inner closes before outer; the instant event fires before outer closes
    assert names == ["inner", "outer.milestone", "outer"]
    spans = [e for e in evs if e["ph"] == "X"]
    for e in spans:
        assert e["dur"] >= 0 and e["ts"] >= 0
    outer_ev = spans[-1]
    assert outer_ev["args"]["stage"] == "profile"


def test_span_records_exception_attr():
    t = obs.configure(trace=True)
    with pytest.raises(ValueError):
        with t.span("boom"):
            raise ValueError("nope")
    (ev,) = t.events()
    assert ev["args"]["error"] == "ValueError"
    assert t.depth() == 0                    # stack unwound


def test_chrome_trace_export_is_loadable(tmp_path):
    t = obs.configure(trace=True)
    with t.span("stage.profile", key="abc123"):
        pass
    path = t.write_chrome(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    assert "traceEvents" in doc
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs == {"M", "X"}                 # metadata + complete spans
    span = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
    assert {"name", "ts", "dur", "pid", "tid", "args"} <= span.keys()


def test_jsonl_sink_streams_and_reads_back(tmp_path):
    t = obs.configure(trace=True, trace_dir=str(tmp_path))
    with t.span("a"):
        pass
    t.event("standalone", n=1)
    t.close()
    evs = read_events(str(tmp_path / "trace.jsonl"))
    assert [e["name"] for e in evs] == ["a", "standalone"]
    # chrome export of the same events reads back identically (minus meta)
    (tmp_path / "trace2.json").write_text(json.dumps(chrome_trace(evs)))
    assert read_events(str(tmp_path / "trace2.json")) == evs


def test_tracer_is_thread_safe():
    t = obs.configure(trace=True)

    barrier = threading.Barrier(4)

    def work(i):
        barrier.wait()                       # overlap all four workers
        for _ in range(50):
            with t.span(f"worker{i}"):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    evs = t.events()
    assert len(evs) == 200                   # no lost appends
    by_name = {f"worker{i}": 0 for i in range(4)}
    for e in evs:
        by_name[e["name"]] += 1
    assert all(v == 50 for v in by_name.values())


def test_span_summary_aggregates_by_name():
    t = obs.configure(trace=True)
    for _ in range(3):
        with t.span("x"):
            pass
    with t.span("y"):
        pass
    rows = {r["name"]: r for r in span_summary(t.events())}
    assert rows["x"]["count"] == 3 and rows["y"]["count"] == 1
    assert rows["x"]["total_ms"] >= rows["x"]["max_ms"]


# -- metrics ------------------------------------------------------------
def test_counter_gauge_histogram_snapshot():
    m = MetricsRegistry()
    m.count("c")
    m.count("c", 2)
    m.record("g", 4.5)
    for v in [1.0, 2.0, 3.0, 4.0]:
        m.observe("h", v)
    snap = m.snapshot()
    assert snap["c"] == {"type": "counter", "value": 3}
    assert snap["g"] == {"type": "gauge", "value": 4.5}
    h = snap["h"]
    assert h["count"] == 4 and h["sum"] == 10.0 and h["mean"] == 2.5
    assert h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] in (2.0, 3.0)
    # round-trips through JSON
    assert json.loads(json.dumps(snap)) == snap


def test_histogram_window_bounds_memory_but_keeps_totals():
    m = MetricsRegistry()
    h = m.histogram("h", window=8)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.max == 99.0 and h.min == 0.0
    assert len(h._recent) == 8               # reservoir stays bounded
    assert h.quantile(0.5) >= 92.0           # quantiles track the window


def test_metric_kind_collision_raises():
    m = MetricsRegistry()
    m.count("x")
    with pytest.raises(TypeError):
        m.gauge("x")


def test_report_renders_every_instrument():
    m = MetricsRegistry()
    m.count("store.hit", 5)
    m.record("train.loss", 1.25)
    m.observe("step_s", 0.5)
    rep = m.report()
    for needle in ("store.hit", "train.loss", "step_s", "counter", "gauge",
                   "histogram"):
        assert needle in rep


# -- logging ------------------------------------------------------------
def test_log_level_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
    assert resolve_level() == logging.INFO
    monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
    assert resolve_level() == logging.DEBUG
    monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
    assert resolve_level() == logging.WARNING
    assert resolve_level("error") == logging.ERROR
    assert resolve_level("17") == 17


def test_kv_lines_are_structured(capsys):
    import io
    buf = io.StringIO()
    logger = setup(level="info", stream=buf)
    obs.log.kv("cache_hit", logger="pipeline", kind="profile",
               key="abc 123", n=3)
    line = buf.getvalue().strip()
    assert "level=info" in line
    assert "logger=repro.pipeline" in line
    assert "event=cache_hit" in line
    assert "kind=profile" in line
    assert 'key="abc 123"' in line           # values with spaces are quoted
    assert "n=3" in line
    # idempotent: re-setup replaces the handler instead of stacking
    setup(level="info", stream=buf)
    assert sum(getattr(h, "_repro_kv", False)
               for h in logger.handlers) == 1


def test_debug_suppressed_at_info(capsys):
    import io
    buf = io.StringIO()
    setup(level="info", stream=buf)
    obs.log.kv("quiet", level=logging.DEBUG)
    assert buf.getvalue() == ""


# -- trainer ring buffer ------------------------------------------------
def test_trainer_metrics_history_is_bounded():
    """_post_step keeps only the newest ``history_cap`` rows while the
    registry keeps full-run aggregates (the unbounded-growth fix)."""
    from repro.train.trainer import Trainer

    tr = object.__new__(Trainer)             # skip the expensive model build
    from collections import deque
    tr.step_times = []
    tr.slow_steps = []
    tr.straggler_factor = 3.0
    tr.metrics_history = deque(maxlen=4)
    tr._tokens_per_step = 128
    tr.builder = None
    for s in range(10):
        tr._post_step(s, 0.01, {"loss": float(s)}, {})
    assert len(tr.metrics_history) == 4
    assert [r["loss"] for r in tr.metrics_history] == [6.0, 7.0, 8.0, 9.0]
    assert tr.metrics_history[-1]["loss"] == 9.0
    m = obs.metrics()
    assert m.value("train.steps") == 10      # full-run total survives the cap
    assert m.value("train.loss") == 9.0
    assert m.snapshot()["train.step_s"]["count"] == 10


# -- CLI ----------------------------------------------------------------
def test_obs_cli_summarizes_and_merges(tmp_path, capsys):
    t = obs.configure(trace=True, trace_dir=str(tmp_path))
    with t.span("stage.profile", key="k1"):
        with t.span("intervals.analyze_batch"):
            pass
    t.close()
    obs.metrics().count("store.miss", 2)
    obs.metrics().write_json(str(tmp_path / "metrics.json"))

    assert obs_cli([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "stage.profile" in out and "intervals.analyze_batch" in out
    assert "store.miss" in out

    merged = tmp_path / "merged.json"
    assert obs_cli([str(tmp_path), "--merge-out", str(merged)]) == 0
    doc = json.loads(merged.read_text())
    assert [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"] \
        == ["intervals.analyze_batch", "stage.profile"]


def test_obs_cli_json_mode(tmp_path, capsys):
    t = obs.configure(trace=True, trace_dir=str(tmp_path))
    with t.span("a"):
        pass
    t.close()
    assert obs_cli([str(tmp_path), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["events"] == 1
    assert doc["spans"][0]["name"] == "a"


def test_obs_cli_no_traces_errors(tmp_path, capsys):
    assert obs_cli([str(tmp_path)]) == 1


# -- env configuration --------------------------------------------------
def test_configure_from_env(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert not obs.configure_from_env().enabled
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert obs.configure_from_env().enabled
    monkeypatch.setenv("REPRO_TRACE", str(tmp_path))
    t = obs.configure_from_env()
    assert t.enabled
    with t.span("x"):
        pass
    t.close()
    assert (tmp_path / "trace.jsonl").exists()
