"""Perf-lever features: int8 weight quant, parallel blocks, remat groups,
causal-skip attention — correctness at smoke scale (the §Perf dry-run
variants build on these)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import layers as L
from repro.models.model_zoo import build_model


@pytest.fixture(scope="module")
def base():
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    return cfg, m, params, {"tokens": toks, "labels": toks}


def test_int8_weight_quant_forward_close(base):
    cfg, m, params, batch = base
    cfg_q = dataclasses.replace(cfg, weight_quant="int8")
    m_q = build_model(cfg_q)
    pq = L.quantize_params(params, m.axes())
    # struct parity with quantized specs
    sq = jax.eval_shape(lambda: m_q.init(jax.random.PRNGKey(0)))
    assert jax.tree.structure(sq) == jax.tree.structure(pq)
    lg, _ = jax.jit(m.forward)(params, batch)
    lq, _ = jax.jit(m_q.forward)(pq, batch)
    rel = float(jnp.mean(jnp.abs(lg - lq)) / jnp.mean(jnp.abs(lg)))
    assert rel < 0.08, rel


def test_int8_quant_decode_consistency(base):
    """Quantized prefill+decode must match quantized teacher forcing."""
    cfg, m, params, batch = base
    cfg_q = dataclasses.replace(cfg, weight_quant="int8")
    m_q = build_model(cfg_q)
    pq = L.quantize_params(params, m.axes())
    full, _ = jax.jit(m_q.forward)(pq, batch)
    cache = m_q.init_cache(2, 24)
    lg, cache, _ = jax.jit(m_q.prefill)(
        pq, {"tokens": batch["tokens"][:, :8]}, cache)
    err = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, 7])))]
    dec = jax.jit(m_q.decode_step)
    for t in range(8, 16):
        lg, cache, _ = dec(pq, batch["tokens"][:, t:t + 1], cache)
        err.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(err) < 2e-4


def test_parallel_block_train_and_decode_agree(base):
    cfg, _, _, batch = base
    cfg_p = dataclasses.replace(cfg, parallel_block=True)
    m_p = build_model(cfg_p)
    params = m_p.init(jax.random.PRNGKey(3))
    full, _ = jax.jit(m_p.forward)(params, batch)
    assert not bool(jnp.isnan(full).any())
    cache = m_p.init_cache(2, 24)
    lg, cache, _ = jax.jit(m_p.prefill)(
        params, {"tokens": batch["tokens"][:, :8]}, cache)
    err = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, 7])))]
    dec = jax.jit(m_p.decode_step)
    for t in range(8, 16):
        lg, cache, _ = dec(params, batch["tokens"][:, t:t + 1], cache)
        err.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(err) < 2e-4


@pytest.mark.slow
def test_remat_group_exact(base):
    cfg, m, params, batch = base
    assert cfg.n_layers % 2 == 0
    cfg_g = dataclasses.replace(cfg, remat_group=2)
    m_g = build_model(cfg_g)
    l1, _ = jax.jit(m.loss)(params, batch)
    l2, _ = jax.jit(m_g.loss)(params, batch)
    assert abs(float(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m_g.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_causal_skip_exact(base):
    cfg, m, params, batch = base
    cfg_s = dataclasses.replace(cfg, attn_causal_skip=True, attn_chunk=8)
    m_s = build_model(cfg_s)
    f1, _ = jax.jit(m.forward)(params, batch)
    f2, _ = jax.jit(m_s.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(f1, np.float32),
                               np.asarray(f2, np.float32),
                               rtol=2e-4, atol=2e-4)
