"""Serving engine: continuous batching, determinism, snapshot/restore,
heterogeneous profiling."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model_zoo import build_model
from repro.serve import Request, ServeEngine, SyntheticRequests


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_engine_completes_all_requests(setup):
    cfg, m, params = setup
    eng = ServeEngine(cfg, batch=3, max_seq=96, prefill_len=16,
                      instrument=False)
    gen = SyntheticRequests(cfg.vocab_size, prompt_len=12, mean_new=8, seed=0)
    reqs = [gen.request(i) for i in range(7)]
    stats = eng.run(params, reqs)
    assert stats["requests"] == 7
    assert stats["tokens"] > 7
    assert stats["tokens_per_s"] > 0
    for r in eng.done:
        assert len(r.output) >= 2


def test_greedy_decoding_deterministic(setup):
    cfg, m, params = setup
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, batch=2, max_seq=64, prefill_len=8,
                          instrument=False)
        gen = SyntheticRequests(cfg.vocab_size, prompt_len=8, mean_new=6,
                                seed=1)
        eng.run(params, [gen.request(i) for i in range(3)])
        outs.append([tuple(r.output) for r in
                     sorted(eng.done, key=lambda r: r.req_id)])
    assert outs[0] == outs[1]


def test_profile_mixes_kinds(setup):
    cfg, m, params = setup
    eng = ServeEngine(cfg, batch=2, max_seq=64, prefill_len=8,
                      interval_steps=2.0)
    gen = SyntheticRequests(cfg.vocab_size, prompt_len=8, mean_new=6, seed=0)
    eng.run(params, [gen.request(i) for i in range(4)])
    assert "prefill" in eng.kinds_log and "decode" in eng.kinds_log
    prof = eng.profile()
    assert prof.n_intervals >= 1
    # prefill and decode blocks both appear in the shared id space
    names = prof.table.names
    assert any(n.startswith("prefill/") for n in names)
    assert any(n.startswith("decode/") for n in names)


def test_snapshot_restore_resumes_identically(setup):
    cfg, m, params = setup
    gen = SyntheticRequests(cfg.vocab_size, prompt_len=8, mean_new=10, seed=2)
    reqs = [gen.request(i) for i in range(2)]

    eng = ServeEngine(cfg, batch=2, max_seq=64, prefill_len=8,
                      instrument=False)
    for r in reqs:
        eng.submit(r)
    for _ in range(5):
        eng.step(params)
    snap = eng.snapshot()
    # continue 3 more steps
    for _ in range(3):
        eng.step(params)
    after_direct = np.asarray(eng.last_token).copy()

    # restore the snapshot into a FRESH engine and replay the same 3 steps
    eng2 = ServeEngine(cfg, batch=2, max_seq=64, prefill_len=8,
                       instrument=False)
    for r in reqs:
        eng2.submit(r)
    for _ in range(5):
        eng2.step(params)
    eng2.restore(snap)
    # sync host-side queue state with eng at snapshot time isn't captured;
    # both engines have identical queues here by construction
    for _ in range(3):
        eng2.step(params)
    np.testing.assert_array_equal(after_direct, np.asarray(eng2.last_token))
