"""Property tests (hypothesis) for the core Nugget machinery: interval
invariants, marker semantics, low-overhead marker search."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.intervals import IntervalBuilder
from repro.core.markers import low_overhead_marker, plan_markers
from repro.core.registry import BlockDef, BlockTable, Segment


def make_table(costs, layers=3):
    blocks = [BlockDef(f"b{i}", float(c)) for i, c in enumerate(costs)]
    prog = [Segment(tuple(range(len(costs))), layers)]
    return BlockTable(blocks, prog)


@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.integers(1, 50), min_size=2, max_size=6),
    layers=st.integers(1, 5),
    n_steps=st.integers(1, 30),
    ivl_frac=st.floats(0.3, 4.0),
)
def test_interval_invariants(costs, layers, n_steps, ivl_frac):
    table = make_table(costs, layers)
    step_uow = table.step_uow()
    b = IntervalBuilder(table, max(1.0, ivl_frac * step_uow))
    for _ in range(n_steps):
        b.add_step()
    prof = b.finalize()

    # 1) total uow == steps × step_uow
    assert prof.total_uow == pytest.approx(n_steps * step_uow)
    # 2) intervals tile the uow axis without gaps
    prev = 0.0
    for iv in prof.intervals:
        assert iv.start_uow == pytest.approx(prev)
        assert iv.end_uow > iv.start_uow
        prev = iv.end_uow
    # 3) interval widths: bounded above by I + one hook; the mean tracks I
    # (fp jitter at exact boundary multiples can shrink individual
    # intervals, so no strict per-interval lower bound)
    widths = [iv.end_uow - iv.start_uow for iv in prof.intervals]
    for w in widths:
        assert w <= prof.interval_uow + max(costs) + 1e-6
    if len(widths) >= 3:
        mean_w = sum(widths) / len(widths)
        assert mean_w >= prof.interval_uow - max(costs) - 1e-6
    # 4) sum of interval BBVs == executions in covered region
    if prof.intervals:
        total_bbv = np.sum([iv.bbv for iv in prof.intervals], axis=0)
        covered = prof.intervals[-1].end_uow
        # count hook stream executions up to covered uow
        ids, cum = table.expand()
        full = np.concatenate([ids] * n_steps)
        cums = np.concatenate([cum + i * step_uow for i in range(n_steps)])
        j = np.searchsorted(cums, covered - 1e-9, side="left") + 1
        want = np.zeros(table.n_blocks)
        np.add.at(want, full[:j], 1)
        np.testing.assert_allclose(total_bbv, want)
    # 5) end markers: cumulative-hit counts are non-decreasing per block
    seen = {}
    for iv in prof.intervals:
        m = iv.end_marker
        assert m.hits >= seen.get(m.block, 0)
        seen[m.block] = m.hits
    # 6) marker uow equals interval end
    for iv in prof.intervals:
        assert iv.end_marker.uow == pytest.approx(iv.end_uow)


@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.integers(1, 40), min_size=3, max_size=6),
    dist_frac=st.floats(0.05, 1.0),
)
def test_low_overhead_marker_properties(costs, dist_frac):
    table = make_table(costs, layers=4)
    b = IntervalBuilder(table, 2.5 * table.step_uow())
    for _ in range(12):
        b.add_step()
    prof = b.finalize()
    if not prof.intervals:
        return
    dist = dist_frac * table.step_uow()
    for idx in range(min(3, prof.n_intervals)):
        iv = prof.intervals[idx]
        m = low_overhead_marker(prof, idx, dist)
        # within the search distance of the interval end
        assert iv.end_uow - m.uow <= dist + 1e-9
        # frequency no higher than the true end block's frequency
        assert iv.bbv[m.block] <= iv.bbv[iv.end_marker.block] + 1e-9 or \
            m.block == iv.end_marker.block


def test_heterogeneous_step_kinds():
    """Serving-style mixed streams: intervals still tile the uow axis."""
    blocks = [BlockDef("p", 10.0), BlockDef("d", 3.0)]
    t = BlockTable(blocks, [Segment((0,), 2)],
                   {"prefill": [Segment((0,), 2)],
                    "decode": [Segment((1,), 4)]})
    b = IntervalBuilder(t, 15.0)
    kinds = ["prefill", "decode", "decode", "prefill", "decode"]
    for k in kinds:
        b.add_step(kind=k)
    prof = b.finalize()
    total = 2 * 20.0 + 3 * 12.0
    assert prof.total_uow == pytest.approx(total)
    prev = 0.0
    for iv in prof.intervals:
        assert iv.start_uow == pytest.approx(prev)
        prev = iv.end_uow


def test_marker_plan_warmup():
    table = make_table([5, 7], layers=2)
    b = IntervalBuilder(table, 1.5 * table.step_uow())
    for _ in range(10):
        b.add_step()
    prof = b.finalize()
    plan = plan_markers(prof, 3, warmup_intervals=2)
    assert plan.warmup_start is not None
    assert plan.warmup_start.uow <= prof.intervals[3].start_uow
    assert 0 <= plan.hook_fraction <= 1
