"""Property tests for k-means / silhouette / selectors."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.intervals import IntervalBuilder
from repro.core.kmeans import kmeans, pick_k_silhouette, random_projection, silhouette
from repro.core.registry import BlockDef, BlockTable, Segment
from repro.core.select import KMeansSelector, RandomSelector, SystematicSelector


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 60),
    d=st.integers(2, 8),
    k=st.integers(2, 5),
    seed=st.integers(0, 100),
)
def test_kmeans_invariants(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    k = min(k, n - 1)
    assign, centers, inertia = kmeans(x, k, seed=seed)
    assert assign.shape == (n,)
    assert assign.min() >= 0 and assign.max() < k
    # every point is assigned to its nearest centroid
    d2 = (np.sum(x * x, 1)[:, None] - 2 * x @ centers.T
          + np.sum(centers * centers, 1)[None])
    np.testing.assert_array_equal(assign, np.argmin(d2, axis=1))
    assert inertia >= 0


def test_kmeans_separated_clusters():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(30, 4)) + 10
    b = rng.normal(size=(30, 4)) - 10
    x = np.concatenate([a, b])
    assign, _, _ = kmeans(x, 2, seed=0)
    assert len(set(assign[:30])) == 1
    assert len(set(assign[30:])) == 1
    assert assign[0] != assign[-1]
    assert silhouette(x, assign) > 0.8
    k, _, _ = pick_k_silhouette(x, max_k=10)
    assert k == 2


def test_random_projection_preserves_relative_distance():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(40, 200))
    xp = random_projection(x, 15, seed=0)
    assert xp.shape == (40, 15)
    # close pairs stay closer than far pairs (JL, loose check)
    d_orig = np.linalg.norm(x[0] - x[1]), np.linalg.norm(x[0] - 10 * x[2])
    d_proj = np.linalg.norm(xp[0] - xp[1]), np.linalg.norm(xp[0] - 10 * random_projection(x, 15, seed=0)[2])
    assert (d_orig[0] < d_orig[1]) == (d_proj[0] < d_proj[1])


def _profile(n_steps=40, seed=0):
    table = BlockTable([BlockDef("a", 10.0), BlockDef("b", 5.0)],
                       [Segment((0, 1), 4)])
    b = IntervalBuilder(table, 2.0 * table.step_uow())
    rng = np.random.default_rng(seed)
    for s in range(n_steps):
        b.add_step()
    return b.finalize()


@pytest.mark.parametrize("selector", [
    RandomSelector(n_samples=8, seed=0),
    SystematicSelector(n_samples=8),
    KMeansSelector(max_k=8, seed=0),
])
def test_selectors_contract(selector):
    prof = _profile()
    sel = selector.select(prof)
    assert len(sel.interval_ids) == len(sel.weights)
    assert len(set(sel.interval_ids)) == len(sel.interval_ids)
    assert all(0 <= i < prof.n_intervals for i in sel.interval_ids)
    assert sel.weights.sum() == pytest.approx(1.0)
    assert (sel.weights > 0).all()
    # sorted ids (stable artifact layout)
    assert sel.interval_ids == sorted(sel.interval_ids)


def test_kmeans_selector_respects_max_k():
    prof = _profile(n_steps=120)
    sel = KMeansSelector(max_k=5, seed=0).select(prof)
    assert len(sel.interval_ids) <= 5
