"""Fault-tolerant pipeline execution: artifact integrity (hash-on-commit,
verify-on-hit, quarantine), the crash-resume run journal, orphan gc, and
the end-to-end fault-injection acceptance runs (slow suite)."""
import dataclasses
import json
import os
import threading

import pytest

from repro import obs
from repro.faults import FaultInjector, InjectedFatal
from repro.pipeline import (
    ArtifactStore, Pipeline, PipelineConfig, RunJournal,
)
from repro.pipeline.stages import Stage


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.configure(trace=False, reset_metrics=True)
    yield
    obs.configure(trace=False, reset_metrics=True)


def _committed_artifact(store, spec=None):
    art = store.resolve("validation", spec or {"x": 1})
    store.write_json(art, "payload.json", {"answer": 42})
    store.write_json(art, "extra.json", [1, 2, 3])
    store.commit(art)
    return art


# -- integrity: hash-on-commit, verify-on-hit, quarantine ---------------
def test_commit_records_payload_hashes(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    with open(os.path.join(art.path, "spec.json")) as f:
        doc = json.load(f)
    assert sorted(doc["files"]) == ["extra.json", "payload.json"]
    import hashlib
    for rel, want in doc["files"].items():
        with open(os.path.join(art.path, rel), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == want


def test_verify_catches_flipped_byte(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    assert store.verify(art) is True
    p = os.path.join(art.path, "payload.json")
    with open(p, "r+b") as f:
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0xFF]))
    assert store.verify(art) is False
    assert store.counters["verified"] == 2
    assert store.counters["verify_s"] > 0


def test_verify_missing_payload_file_fails(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    os.unlink(os.path.join(art.path, "extra.json"))
    assert store.verify(art) is False


def test_legacy_artifact_without_hashes_passes(tmp_path):
    # artifacts committed before integrity recording have no "files"
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    marker = os.path.join(art.path, "spec.json")
    with open(marker) as f:
        doc = json.load(f)
    del doc["files"]
    with open(marker, "w") as f:
        json.dump(doc, f)
    assert store.verify(art) is True
    assert store.lookup(art) is True


def test_lookup_quarantines_corrupt_artifact(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    with open(os.path.join(art.path, "payload.json"), "ab") as f:
        f.write(b"garbage")
    assert store.lookup(art) is False          # corrupt hit -> miss
    assert not os.path.exists(art.path)        # moved out of the cache
    qdir = os.path.join(store.root, ArtifactStore.QUARANTINE)
    assert os.listdir(qdir) == [f"validation-{art.key}"]
    assert store.counters["quarantined"] == 1
    # same key re-quarantined later gets a distinct suffix
    _committed_artifact(store)
    with open(os.path.join(art.path, "payload.json"), "ab") as f:
        f.write(b"garbage")
    assert store.lookup(art) is False
    assert sorted(os.listdir(qdir)) == [
        f"validation-{art.key}", f"validation-{art.key}.1"]


class _PayloadStage(Stage):
    kind = "validation"
    name = "payload"

    def __init__(self):
        self.computes = 0

    def spec(self, ctx):
        return {"fixed": 1}

    def compute(self, ctx):
        self.computes += 1
        return {"value": 42}

    def save(self, store, art, payload):
        store.write_json(art, "payload.json", payload)

    def load(self, store, art):
        return store.read_json(art, "payload.json")


class _StageCtx:
    def __init__(self, store):
        self.store = store
        self.records = []

    def record(self, stage, art, payload, hit, wall_s):
        self.records.append((payload, hit))


def test_corrupt_artifact_recomputed_as_plain_miss(tmp_path):
    store = ArtifactStore(str(tmp_path))
    stage, ctx = _PayloadStage(), _StageCtx(store)
    art = stage.run(ctx)
    with open(os.path.join(art.path, "payload.json"), "ab") as f:
        f.write(b"!")
    stage.run(ctx)                              # quarantine + recompute
    stage.run(ctx)                              # clean hit again
    assert stage.computes == 2
    assert [h for _, h in ctx.records] == [False, False, True]
    assert all(p == {"value": 42} for p, _ in ctx.records)


def test_injector_corruption_caught_on_next_lookup(tmp_path):
    inj = FaultInjector.from_spec("corrupt:stage=validation,n=1")
    store = ArtifactStore(str(tmp_path), injector=inj)
    stage, ctx = _PayloadStage(), _StageCtx(store)
    stage.run(ctx)                              # commit corrupts the payload
    assert inj.rules[0].fired == 1
    stage.run(ctx)                              # verify -> quarantine -> redo
    assert stage.computes == 2
    assert store.counters["quarantined"] == 1


# -- atomic write_json --------------------------------------------------
def test_write_json_leaves_no_temp_files(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = store.resolve("validation", {"x": 2})
    store.write_json(art, "payload.json", {"ok": True})
    assert not [f for f in os.listdir(art.path) if f.endswith(".tmp")]


def test_write_json_failure_preserves_existing_payload(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = store.resolve("validation", {"x": 3})
    store.write_json(art, "payload.json", {"ok": True})
    with pytest.raises(TypeError):
        store.write_json(art, "payload.json", {"bad": object()})
    assert store.read_json(art, "payload.json") == {"ok": True}
    assert not [f for f in os.listdir(art.path) if f.endswith(".tmp")]


# -- orphans + gc -------------------------------------------------------
def test_orphans_listed_and_gced_committed_survive(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = _committed_artifact(store)
    orphan = store.resolve("validation", {"crashed": True})
    store.write_json(orphan, "partial.json", {"half": "written"})
    assert store.orphans("validation") == [orphan.key]
    assert store.keys("validation") == [art.key]
    removed = store.gc()
    assert removed == [f"validation/{orphan.key}"]
    assert not os.path.exists(orphan.path)
    assert os.path.exists(art.path)             # committed untouched
    assert store.orphans("validation") == []


def test_gc_min_age_spares_fresh_orphans(tmp_path):
    store = ArtifactStore(str(tmp_path))
    orphan = store.resolve("validation", {"inflight": True})
    store.write_json(orphan, "partial.json", {})
    assert store.gc(min_age_s=3600) == []       # too fresh: in-flight peer?
    assert os.path.exists(orphan.path)
    assert store.gc() == [f"validation/{orphan.key}"]


# -- run journal --------------------------------------------------------
def test_journal_roundtrip_and_committed(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with RunJournal(path) as j:
        j.append("run_start", run_key="abc")
        j.append("stage_start", stage="profile", key="k1")
        j.append("stage_commit", stage="profile", key="k1", cache_hit=False)
        j.append("stage_start", stage="select", key="k2")
    events = RunJournal.read(path)
    assert [e["kind"] for e in events] == [
        "run_start", "stage_start", "stage_commit", "stage_start"]
    assert all("t" in e for e in events)
    # only committed stages resume; the torn stage_start does not
    assert RunJournal.committed(events) == {"profile": "k1"}


def test_journal_read_skips_torn_tail(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with RunJournal(path) as j:
        j.append("stage_commit", stage="mark", key="k9", cache_hit=False)
    with open(path, "a") as f:
        f.write('{"kind": "stage_co')        # crash mid-append
    events = RunJournal.read(path)
    assert len(events) == 1
    assert RunJournal.committed(events) == {"mark": "k9"}
    assert RunJournal.read(str(tmp_path / "missing.jsonl")) == []


def test_journal_threadsafe_append(tmp_path):
    path = str(tmp_path / "run.jsonl")
    j = RunJournal(path)
    threads = [threading.Thread(
        target=lambda i=i: j.append("stage_commit", stage=f"s{i}",
                                    key=f"k{i}", cache_hit=False))
        for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    j.close()
    events = RunJournal.read(path)
    assert len(events) == 16
    assert len(RunJournal.committed(events)) == 16


# -- end-to-end crash-resume + fault storm (slow suite) -----------------
CFG = PipelineConfig(
    arch="olmoe-1b-7b", platforms=("f32",), selector="random",
    selector_args={"n_samples": 3, "seed": 0},
    steps=8, seq_len=16, batch=2, interval_steps=2.0, seed=0)


def test_run_key_ignores_execution_fields():
    serial = CFG
    tuned = dataclasses.replace(CFG, workers=4, max_attempts=7,
                                retry_backoff_s=1.0, stage_timeout_s=60.0,
                                gc_orphans=False)
    assert serial.run_key() == tuned.run_key()
    assert serial.run_key() != dataclasses.replace(CFG, steps=9).run_key()


def _keys(manifest):
    return {s["stage"]: s["key"] for s in manifest["stages"]}


def _hits(manifest):
    return {s["stage"]: s["cache_hit"] for s in manifest["stages"]}


@pytest.mark.slow
def test_crash_resume_bit_identical(tmp_path):
    """A run killed mid-graph resumes from committed artifacts and ends
    with digests identical to an uninterrupted run."""
    ref = Pipeline(CFG, str(tmp_path / "clean")).run()
    store = str(tmp_path / "crashed")
    inj = FaultInjector.from_spec("fatal:stage=baseline@f32")
    with pytest.raises(InjectedFatal):
        Pipeline(CFG, store, fault_injector=inj).run()
    jpath = os.path.join(store, ".journal", f"run-{CFG.run_key()}.jsonl")
    committed = RunJournal.committed(RunJournal.read(jpath))
    assert committed, "crash must leave committed stages behind"
    resumed = Pipeline(CFG, store).run()
    ft = resumed["fault_tolerance"]
    assert sorted(committed) == ft["resumed_stages"]
    for stage in committed:
        assert _hits(resumed)[stage], f"{stage} must warm-resume"
    assert _keys(resumed) == _keys(ref)
    assert resumed["fault_tolerance"]["quarantined"] == 0


@pytest.mark.slow
def test_fault_storm_still_converges(tmp_path):
    """Acceptance: transient raises at p=0.3, one corrupted payload and
    one worker kill — the run completes with digests equal to a clean
    run, and the corruption is quarantined on the next warm pass."""
    ref = Pipeline(CFG, str(tmp_path / "clean")).run()
    storm_cfg = dataclasses.replace(CFG, workers=2, max_attempts=5,
                                    retry_backoff_s=0.01)
    store = str(tmp_path / "storm")
    inj = FaultInjector.from_spec(
        "raise:p=0.3;corrupt:stage=profile,n=1;kill:n=1", seed=3)
    manifest = Pipeline(storm_cfg, store, fault_injector=inj).run()
    assert _keys(manifest) == _keys(ref)
    ft = manifest["fault_tolerance"]
    assert ft["retries"] > 0
    assert ft["worker_failures"] == 1
    fired = {e["kind"] for e in ft["faults"]["events"]}
    assert fired == {"raise", "kill", "corrupt"}
    # warm rerun: the corrupted profile is quarantined + recomputed,
    # every clean downstream artifact hits (input-addressed keys held)
    rerun = Pipeline(CFG, store).run()
    assert _keys(rerun) == _keys(ref)
    hits = _hits(rerun)
    assert hits["profile"] is False
    assert all(h for s, h in hits.items() if s != "profile")
    assert rerun["fault_tolerance"]["quarantined"] == 1
    qdir = os.path.join(store, ArtifactStore.QUARANTINE)
    assert any(n.startswith("profile-") for n in os.listdir(qdir))
