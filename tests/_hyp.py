"""Optional-hypothesis shim for property-test modules.

``hypothesis`` is not a hard dependency of the repo.  Test modules import
``given``/``settings``/``st`` from here instead of from ``hypothesis``
directly: when hypothesis is installed the real objects are re-exported;
when it is missing, property tests collect as skips (and the plain unit
tests in the same modules still run).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
