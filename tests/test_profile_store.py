"""Round-trip + cache tests for the profile store."""
import numpy as np

from repro.core.intervals import IntervalBuilder, build_profile
from repro.core.intervals_vec import as_steps
from repro.core.profile_store import (cached_build, cached_finalize,
                                      load_profile, profile_cache_key,
                                      save_profile, stream_digest)
from repro.core.registry import BlockDef, BlockTable, Segment


def small_table():
    return BlockTable([BlockDef("a", 10.0), BlockDef("b", 5.0),
                       BlockDef("v", 0.0, virtual=True, dyn_key="aux")],
                      [Segment((0, 1), 3)])


def test_zero_interval_roundtrip_keeps_block_dim(tmp_path):
    table = small_table()
    # interval far bigger than the stream -> no interval ever closes
    profile = build_profile(table, 1e9, as_steps(n_steps=3))
    assert profile.n_intervals == 0
    save_profile(str(tmp_path), profile)
    loaded = load_profile(str(tmp_path))
    assert loaded.n_intervals == 0
    assert loaded.bbv_matrix().shape == (0, table.n_blocks)
    z = np.load(tmp_path / "profile.npz")
    assert z["bbvs"].shape == (0, table.n_blocks)
    assert z["stamps"].shape == (0, table.n_blocks)
    assert z["hits_at"].shape == (0, table.n_blocks)


def test_roundtrip_preserves_intervals(tmp_path):
    table = small_table()
    steps = as_steps(n_steps=9,
                     dyn_per_step=[{"aux": float(i)} for i in range(9)])
    profile = build_profile(table, table.step_uow() * 1.4, steps)
    assert profile.n_intervals > 0
    save_profile(str(tmp_path), profile)
    loaded = load_profile(str(tmp_path))
    assert loaded.n_intervals == profile.n_intervals
    for a, b in zip(profile.intervals, loaded.intervals):
        assert a.end_marker == b.end_marker
        assert np.array_equal(a.bbv, b.bbv)
        assert np.array_equal(a.stamps, b.stamps)
        assert np.array_equal(a.hits_at_stamp, b.hits_at_stamp)
    assert np.array_equal(loaded.dyn_history["aux"],
                          profile.dyn_history["aux"])


def test_cache_hit_returns_equal_profile(tmp_path):
    table = small_table()
    steps = as_steps(n_steps=12,
                     dyn_per_step=[{"aux": float(i % 3)} for i in range(12)])
    iu = table.step_uow() * 0.8
    p1, hit1 = cached_build(str(tmp_path), table, iu, steps)
    p2, hit2 = cached_build(str(tmp_path), table, iu, steps)
    assert not hit1 and hit2
    assert p2.n_intervals == p1.n_intervals
    for a, b in zip(p1.intervals, p2.intervals):
        assert a.end_marker == b.end_marker
        assert np.array_equal(a.bbv, b.bbv)


def test_cache_invalidation(tmp_path):
    table = small_table()
    steps = as_steps(n_steps=10)
    iu = table.step_uow() * 0.8
    _, hit = cached_build(str(tmp_path), table, iu, steps)
    assert not hit
    # changed interval size -> miss
    _, hit = cached_build(str(tmp_path), table, iu * 2, steps)
    assert not hit
    # changed dyn values -> miss
    steps_dyn = as_steps(n_steps=10, dyn_per_step=[{"aux": 1.0}] * 10)
    _, hit = cached_build(str(tmp_path), table, iu, steps_dyn)
    assert not hit
    # changed step kind stream -> different digest
    assert stream_digest(steps) != stream_digest([("decode", None)] * 10)
    # changed table -> different key
    other = BlockTable([BlockDef("a", 11.0), BlockDef("b", 5.0)],
                       [Segment((0, 1), 3)])
    assert profile_cache_key(table, iu, steps) != \
        profile_cache_key(other, iu, steps)


def test_stream_digest_ignores_dict_order():
    s1 = [("default", {"a": 1.0, "b": 2.0})]
    s2 = [("default", {"b": 2.0, "a": 1.0})]
    assert stream_digest(s1) == stream_digest(s2)


def test_cached_finalize_with_deferred_builder(tmp_path):
    table = small_table()
    steps = as_steps(n_steps=15)
    iu = table.step_uow() * 1.1
    b1 = IntervalBuilder(table, iu, defer=True)
    for k, d in steps:
        b1.add_step(d, kind=k)
    p1, hit1 = cached_finalize(str(tmp_path), b1)
    assert not hit1

    b2 = IntervalBuilder(table, iu, defer=True)
    for k, d in steps:
        b2.add_step(d, kind=k)
    p2, hit2 = cached_finalize(str(tmp_path), b2)
    assert hit2
    assert b2.intervals == []            # analysis was skipped entirely
    assert p2.n_intervals == p1.n_intervals
