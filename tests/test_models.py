"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward/train step on CPU with correct shapes and
no NaNs; plus prefill+decode vs teacher-forced-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import constant
from repro.train.state import init_train_state, make_train_step

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model))
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch, rng_key):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(rng_key)
    batch = _batch(cfg, rng_key)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 16, m.dims.vocab_pad)
    assert not bool(jnp.isnan(logits).any())
    if cfg.family == "moe":
        # expert token counts accumulate over layers
        assert int(aux["expert_tokens"].sum()) == \
            2 * 16 * cfg.moe.top_k * cfg.n_layers


# the heaviest configs only train-step / decode-check in the slow suite;
# their forward-shape coverage stays in the default run
_SLOW_TRAIN_STEP = {"whisper-tiny", "zamba2-1.2b", "llama4-scout-17b-a16e",
                    "mamba2-780m"}
_SLOW_DECODE = {"whisper-tiny", "zamba2-1.2b", "llama4-scout-17b-a16e"}


def _maybe_slow(archs, slow_set):
    return [pytest.param(a, marks=pytest.mark.slow) if a in slow_set else a
            for a in archs]


@pytest.mark.parametrize("arch", _maybe_slow(ARCHS, _SLOW_TRAIN_STEP))
def test_smoke_train_step(arch, rng_key):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(m, rng_key, opt)
    step = jax.jit(make_train_step(m, opt, constant(1e-3), instrument=False))
    batch = _batch(cfg, rng_key)
    state2, metrics, aux = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params must actually change
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", _maybe_slow(ARCHS, _SLOW_DECODE))
def test_prefill_decode_matches_forward(arch, rng_key):
    cfg = reduced(get_config(arch))
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(rng_key)
    B, S, P = 2, 16, 8
    batch = _batch(cfg, rng_key, B, S)
    full_logits, _ = jax.jit(m.forward)(params, batch)
    cache = m.init_cache(B, S + 4)
    lg, cache, _ = jax.jit(m.prefill)(
        params, {**batch, "tokens": batch["tokens"][:, :P]}, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, P - 1])))]
    dec = jax.jit(m.decode_step)
    for t in range(P, S):
        lg, cache, _ = dec(params, batch["tokens"][:, t:t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-4, errs


@pytest.mark.slow
def test_microbatch_equals_full_batch(rng_key):
    """Gradient accumulation must match the single-shot step numerically."""
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    batch = _batch(cfg, rng_key, B=4, S=16)
    s0 = init_train_state(m, rng_key, opt)
    step1 = jax.jit(make_train_step(m, opt, constant(1e-3), instrument=False))
    step2 = jax.jit(make_train_step(m, opt, constant(1e-3), microbatch=2,
                                    instrument=False))
    s1, m1, _ = step1(s0, batch)
    s2, m2, _ = step2(s0, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_attention_impls_agree(rng_key):
    """reference vs chunked vs pallas attention on the same dense model."""
    base = reduced(get_config("qwen3-1.7b"))
    m_ref = build_model(dataclasses.replace(base, attention_impl="reference"))
    params = m_ref.init(rng_key)
    batch = _batch(base, rng_key, B=2, S=32)
    out_ref, _ = jax.jit(m_ref.forward)(params, batch)
    for impl in ("chunked", "pallas"):
        cfg = dataclasses.replace(base, attention_impl=impl, attn_chunk=16)
        m = build_model(cfg)
        out, _ = jax.jit(m.forward)(params, batch)
        err = float(jnp.max(jnp.abs(out - out_ref)))
        assert err < 2e-4, (impl, err)


def test_sliding_window_differs_from_global(rng_key):
    cfg = reduced(get_config("gemma3-4b"))
    m = build_model(cfg)
    params = m.init(rng_key)
    batch = _batch(cfg, rng_key, B=1, S=32)
    out_local, _ = jax.jit(m.forward)(params, batch)
    cfg_g = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, local_window=0, global_every=0))
    out_global, _ = jax.jit(build_model(cfg_g).forward)(params, batch)
    assert float(jnp.max(jnp.abs(out_local - out_global))) > 1e-6


def test_param_count_analytic_matches_actual(rng_key):
    from repro.models.layers import param_count
    for arch in ("qwen3-1.7b", "mamba2-780m", "olmoe-1b-7b"):
        cfg = get_config(arch)
        m = build_model(cfg)
        actual = sum(int(np.prod(s.shape))
                     for s in jax.tree.leaves(jax.eval_shape(
                         lambda: m.init(jax.random.PRNGKey(0)))))
        analytic = cfg.param_count()
        # within 2% (analytic skips small norm/bias terms)
        assert abs(actual - analytic) / analytic < 0.02, (arch, actual, analytic)
