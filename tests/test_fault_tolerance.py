"""Fault tolerance: heartbeat coordinator, failure-injected training run
recovers via checkpoints and matches the uninterrupted run."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.distributed.faults import (FaultInjectingRun, HeartbeatCoordinator)
from repro.train import Trainer


def test_heartbeat_detects_dead_worker():
    co = HeartbeatCoordinator(3, timeout_s=0.05)
    co.heartbeat(0, 1)
    co.heartbeat(1, 1)
    co.heartbeat(2, 1)
    time.sleep(0.08)
    co.heartbeat(0, 2)
    co.heartbeat(1, 2)
    dead = co.check()
    assert dead == [2]
    assert co.alive_count() == 2
    assert any(e["kind"] == "dead" for e in co.events)


def test_straggler_strikes_recorded():
    co = HeartbeatCoordinator(2, timeout_s=10, straggler_factor=2.0)
    for s in range(20):
        co.heartbeat(0, s, step_time_s=0.1)
    co.heartbeat(1, 20, step_time_s=1.0)      # 10x median
    assert any(e["kind"] == "straggler" for e in co.events)


def test_step_time_window_is_per_instance():
    """Regression: ``_times`` was a class attribute, so a coordinator
    inherited another's step-time history — a fresh fleet's first slow
    sample compared against a stale median and flagged a phantom
    straggler."""
    co1 = HeartbeatCoordinator(1, timeout_s=10, straggler_factor=2.0)
    for s in range(20):
        co1.heartbeat(0, s, step_time_s=0.1)
    co2 = HeartbeatCoordinator(1, timeout_s=10, straggler_factor=2.0)
    co2.heartbeat(0, 0, step_time_s=1.0)      # its own first sample
    assert co2._times == [1.0]
    assert not co2.events, "fresh coordinator must not inherit medians"
    assert co2.workers[0].slow_strikes == 0


@pytest.mark.slow
def test_fault_injected_training_matches_uninterrupted(tmp_path):
    """Kill the 'fleet' at steps 7 and 13; restart from checkpoints; the
    final params must equal an uninterrupted run bit-for-bit (deterministic
    data cursor + saved rng/opt state)."""
    cfg = reduced(get_config("qwen3-1.7b"))

    # ground truth: uninterrupted
    t_ref = Trainer(cfg, seq_len=16, batch=2, instrument=False, donate=False)
    s_ref = t_ref.run(16)

    ck = str(tmp_path / "ck")
    tr = Trainer(cfg, seq_len=16, batch=2, instrument=False,
                 ckpt_dir=ck, ckpt_every=5, donate=False)

    state_box = {"state": None}

    def run_steps(frm: int, to: int) -> int:
        # restart path: restore from latest checkpoint like a fresh process
        t2 = Trainer(cfg, seq_len=16, batch=2, instrument=False,
                     ckpt_dir=ck, ckpt_every=5, donate=False)
        st = t2.run(to)
        state_box["state"] = st
        return int(st.step)

    run = FaultInjectingRun(4, run_steps, ckpt_every=5,
                            kill_at={1: 7, 2: 13})
    final_step = run.run(16)
    assert final_step == 16
    assert run.restarts == 2
    got = state_box["state"]
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
