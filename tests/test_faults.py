"""Unit tests for the shared failure vocabulary (``repro.faults``):
spec parsing, transient/fatal classification, retry backoff, and the
deterministic fault injector."""
import os
import time

import pytest

from repro import obs
from repro.faults import (
    FaultInjector, FaultError, InjectedFatal, InjectedFault, RetryPolicy,
    StageTimeout, TransientError, WorkerKilled, classify, fault_event,
    parse_fault_spec,
)


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.configure(trace=False, reset_metrics=True)
    yield
    obs.configure(trace=False, reset_metrics=True)


# -- spec parsing -------------------------------------------------------
def test_parse_single_rule_defaults():
    (r,) = parse_fault_spec("raise")
    assert (r.kind, r.stage, r.p, r.n, r.s) == ("raise", "*", 1.0, -1, 0.0)


def test_parse_destructive_kinds_default_one_shot():
    for kind in ("kill", "stall", "corrupt", "fatal"):
        (r,) = parse_fault_spec(kind)
        assert r.n == 1, f"{kind} must default to a budget of one firing"
    (r,) = parse_fault_spec("kill:n=5")
    assert r.n == 5                     # explicit budget wins


def test_parse_params_and_multiple_rules():
    rules = parse_fault_spec(
        "raise:stage=profile,p=0.3; corrupt:stage=baseline@*,n=2 ;"
        "stall:s=1.5")
    assert [r.kind for r in rules] == ["raise", "corrupt", "stall"]
    assert rules[0].stage == "profile" and rules[0].p == 0.3
    assert rules[1].stage == "baseline@*" and rules[1].n == 2
    assert rules[2].s == 1.5


@pytest.mark.parametrize("bad", [
    "explode",                 # unknown kind
    "raise:p0.3",              # malformed param (no '=')
    "raise:frequency=2",       # unknown param
])
def test_parse_malformed_raises(bad):
    with pytest.raises(ValueError):
        parse_fault_spec(bad)


# -- classification -----------------------------------------------------
@pytest.mark.parametrize("exc", [
    TransientError("t"), InjectedFault("i"), StageTimeout("s"),
    WorkerKilled("w"), OSError("os"), ConnectionError("c"),
    TimeoutError("to"),
])
def test_classify_transient(exc):
    assert classify(exc) == "transient"


@pytest.mark.parametrize("exc", [
    ValueError("v"), AssertionError("a"), InjectedFatal("f"),
    FaultError("base"), RuntimeError("r"),
])
def test_classify_fatal(exc):
    assert classify(exc) == "fatal"


def test_fault_event_shape():
    assert fault_event("dead", worker=3) == {"kind": "dead", "worker": 3}


# -- retry policy -------------------------------------------------------
def test_delay_deterministic_and_exponential():
    p = RetryPolicy(backoff_s=0.05, backoff_factor=2.0, jitter_frac=0.25)
    d1, d2, d3 = (p.delay("mark", k) for k in (1, 2, 3))
    assert d1 == p.delay("mark", 1)     # no global RNG: replays identically
    # jitter is bounded to [1, 1.25): successive attempts strictly grow
    assert 0.05 <= d1 < 0.05 * 1.25
    assert 0.10 <= d2 < 0.10 * 1.25
    assert 0.20 <= d3 < 0.20 * 1.25
    assert p.delay("mark", 1) != p.delay("profile", 1)  # per-key jitter


def test_delay_caps_at_max_backoff():
    p = RetryPolicy(backoff_s=1.0, max_backoff_s=4.0, jitter_frac=0.0)
    assert p.delay("x", 50) == 4.0


# -- injector -----------------------------------------------------------
def test_injector_replays_identically():
    spec, seed = "raise:p=0.4", 7
    def schedule():
        inj = FaultInjector.from_spec(spec, seed=seed)
        fired = []
        for i in range(50):
            try:
                inj.fire("stage", f"site{i % 3}")
            except InjectedFault:
                fired.append(i)
        return fired
    a, b = schedule(), schedule()
    assert a == b and 0 < len(a) < 50


def test_injector_different_seed_different_schedule():
    def schedule(seed):
        inj = FaultInjector.from_spec("raise:p=0.5", seed=seed)
        fired = []
        for i in range(64):
            try:
                inj.fire("stage", "s")
            except InjectedFault:
                fired.append(i)
        return fired
    assert schedule(1) != schedule(2)


def test_kill_budget_is_one_shot():
    inj = FaultInjector.from_spec("kill")
    with pytest.raises(WorkerKilled):
        inj.fire("stage", "mark")
    inj.fire("stage", "mark")           # budget spent: no raise
    (rule,) = inj.rules
    assert rule.fired == 1 and rule.calls == 2


def test_stage_filter_is_fnmatch():
    inj = FaultInjector.from_spec("raise:stage=baseline@*,n=-1")
    inj.fire("stage", "profile")        # filtered: no raise
    with pytest.raises(InjectedFault):
        inj.fire("stage", "baseline@f32")
    with pytest.raises(InjectedFault):
        inj.fire("stage", "baseline@bf16")


def test_fatal_rule_raises_injected_fatal():
    inj = FaultInjector.from_spec("fatal:stage=mark")
    with pytest.raises(InjectedFatal):
        inj.fire("stage", "mark")
    assert classify(InjectedFatal("x")) == "fatal"


def test_stall_sleeps():
    inj = FaultInjector.from_spec("stall:s=0.05")
    t0 = time.perf_counter()
    inj.fire("stage", "any")
    assert time.perf_counter() - t0 >= 0.05
    t0 = time.perf_counter()
    inj.fire("stage", "any")            # one-shot: second call is free
    assert time.perf_counter() - t0 < 0.05


def test_corrupt_flips_payload_byte(tmp_path):
    d = tmp_path / "artifact"
    d.mkdir()
    (d / "payload.json").write_bytes(b'{"v": 1}')
    (d / "spec.json").write_bytes(b'{"key": "k"}')
    inj = FaultInjector.from_spec("corrupt")
    assert inj.fire("stage", "any") is None       # corrupt ignores fire()
    assert inj.corrupt(str(d), "profile") is True
    assert (d / "payload.json").read_bytes()[0] == b"{"[0] ^ 0xFF
    assert (d / "spec.json").read_bytes() == b'{"key": "k"}'  # marker intact
    assert inj.corrupt(str(d), "profile") is False  # budget spent


def test_corrupt_refunds_budget_when_nothing_to_corrupt(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    inj = FaultInjector.from_spec("corrupt")
    assert inj.corrupt(str(empty), "profile") is False
    assert inj.rules[0].fired == 0      # refunded: still armed
    full = tmp_path / "full"
    full.mkdir()
    (full / "data.bin").write_bytes(b"\x00\x01")
    assert inj.corrupt(str(full), "profile") is True
    assert (full / "data.bin").read_bytes() == b"\xff\x01"


def test_events_and_summary_account_firings():
    inj = FaultInjector.from_spec("raise:n=1;kill:n=1")
    with pytest.raises(InjectedFault):
        inj.fire("stage", "a")
    with pytest.raises(WorkerKilled):
        inj.fire("stage", "b")
    s = inj.summary()
    assert [e["kind"] for e in s["events"]] == ["raise", "kill"]
    assert [e["site"] for e in s["events"]] == ["a", "b"]
    assert all(r["fired"] == 1 for r in s["rules"])
    snap = obs.metrics().snapshot()
    assert snap["faults.raise"]["value"] == 1
    assert snap["faults.kill"]["value"] == 1


# -- env construction ---------------------------------------------------
def test_from_env_unset_returns_none():
    assert FaultInjector.from_env({}) is None
    assert FaultInjector.from_env({"REPRO_FAULTS": "  "}) is None


def test_from_env_builds_with_seed():
    inj = FaultInjector.from_env({"REPRO_FAULTS": "raise:p=0.1;kill",
                                  "REPRO_FAULT_SEED": "42"})
    assert inj is not None and inj.seed == 42
    assert [r.kind for r in inj.rules] == ["raise", "kill"]


def test_from_env_reads_process_environ(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "stall:s=0")
    monkeypatch.setenv("REPRO_FAULT_SEED", "3")
    inj = FaultInjector.from_env()
    assert inj is not None and inj.seed == 3 and inj.rules[0].kind == "stall"
    monkeypatch.delenv("REPRO_FAULTS")
    assert FaultInjector.from_env() is None
